//! Binary model serialization (no `serde` available — a small
//! length-prefixed little-endian format with magic/version header).
//!
//! Derived structures (MPH lookups, KSE schedule tables, the i8
//! reference prototypes) are *rebuilt* on load: they are deterministic
//! functions of the stored codebooks / histogram matrices / packed
//! prototypes, which keeps the artifact compact and guarantees the
//! offline tables always match the deployed parameters.
//!
//! ## Format versions
//!
//! * v1 (`NYSXMDL\x01`): prototypes stored as i8 bytes (d bytes each).
//!   Still read transparently.
//! * v2 (`NYSXMDL\x02`, current): prototypes stored bit-packed (one sign
//!   bit per element, `⌈d/64⌉` u64 words each — 8× smaller), with
//!   tail-bit validation on load.

use std::io::{self, Read, Write};

use super::{ModelConfig, NysHdcModel};
use crate::hdc::{ClassPrototypes, Hypervector, PackedHypervector, PackedPrototypes};
use crate::kernel::{Codebook, LshParams};
use crate::mph::{code_key, MphLookup};
use crate::nystrom::{LandmarkStrategy, NystromProjection};
use crate::sparse::Csr;

const MAGIC_V1: &[u8; 8] = b"NYSXMDL\x01";
const MAGIC: &[u8; 8] = b"NYSXMDL\x02";

struct Writer<W: Write> {
    w: W,
}

impl<W: Write> Writer<W> {
    fn u64(&mut self, v: u64) -> io::Result<()> {
        self.w.write_all(&v.to_le_bytes())
    }
    fn i64(&mut self, v: i64) -> io::Result<()> {
        self.w.write_all(&v.to_le_bytes())
    }
    fn f64(&mut self, v: f64) -> io::Result<()> {
        self.w.write_all(&v.to_le_bytes())
    }
    fn bytes(&mut self, v: &[u8]) -> io::Result<()> {
        self.u64(v.len() as u64)?;
        self.w.write_all(v)
    }
    fn str(&mut self, s: &str) -> io::Result<()> {
        self.bytes(s.as_bytes())
    }
    fn f64s(&mut self, v: &[f64]) -> io::Result<()> {
        self.u64(v.len() as u64)?;
        for &x in v {
            self.f64(x)?;
        }
        Ok(())
    }
    fn f32s(&mut self, v: &[f32]) -> io::Result<()> {
        self.u64(v.len() as u64)?;
        for &x in v {
            self.w.write_all(&x.to_le_bytes())?;
        }
        Ok(())
    }
    fn i64s(&mut self, v: &[i64]) -> io::Result<()> {
        self.u64(v.len() as u64)?;
        for &x in v {
            self.i64(x)?;
        }
        Ok(())
    }
    fn usizes(&mut self, v: &[usize]) -> io::Result<()> {
        self.u64(v.len() as u64)?;
        for &x in v {
            self.u64(x as u64)?;
        }
        Ok(())
    }
    fn u32s(&mut self, v: &[u32]) -> io::Result<()> {
        self.u64(v.len() as u64)?;
        for &x in v {
            self.w.write_all(&x.to_le_bytes())?;
        }
        Ok(())
    }
    fn u64s(&mut self, v: &[u64]) -> io::Result<()> {
        self.u64(v.len() as u64)?;
        for &x in v {
            self.u64(x)?;
        }
        Ok(())
    }
}

struct Reader<R: Read> {
    r: R,
}

impl<R: Read> Reader<R> {
    fn u64(&mut self) -> io::Result<u64> {
        let mut b = [0u8; 8];
        self.r.read_exact(&mut b)?;
        Ok(u64::from_le_bytes(b))
    }
    fn i64(&mut self) -> io::Result<i64> {
        let mut b = [0u8; 8];
        self.r.read_exact(&mut b)?;
        Ok(i64::from_le_bytes(b))
    }
    fn f64(&mut self) -> io::Result<f64> {
        let mut b = [0u8; 8];
        self.r.read_exact(&mut b)?;
        Ok(f64::from_le_bytes(b))
    }
    fn bytes(&mut self) -> io::Result<Vec<u8>> {
        let n = self.u64()? as usize;
        let mut v = vec![0u8; n];
        self.r.read_exact(&mut v)?;
        Ok(v)
    }
    fn str(&mut self) -> io::Result<String> {
        String::from_utf8(self.bytes()?)
            .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e))
    }
    fn f64s(&mut self) -> io::Result<Vec<f64>> {
        let n = self.u64()? as usize;
        (0..n).map(|_| self.f64()).collect()
    }
    fn f32s(&mut self) -> io::Result<Vec<f32>> {
        let n = self.u64()? as usize;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let mut b = [0u8; 4];
            self.r.read_exact(&mut b)?;
            out.push(f32::from_le_bytes(b));
        }
        Ok(out)
    }
    fn i64s(&mut self) -> io::Result<Vec<i64>> {
        let n = self.u64()? as usize;
        (0..n).map(|_| self.i64()).collect()
    }
    fn usizes(&mut self) -> io::Result<Vec<usize>> {
        let n = self.u64()? as usize;
        (0..n).map(|_| self.u64().map(|v| v as usize)).collect()
    }
    fn u32s(&mut self) -> io::Result<Vec<u32>> {
        let n = self.u64()? as usize;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let mut b = [0u8; 4];
            self.r.read_exact(&mut b)?;
            out.push(u32::from_le_bytes(b));
        }
        Ok(out)
    }
    fn i8s(&mut self) -> io::Result<Vec<i8>> {
        let bytes = self.bytes()?;
        Ok(bytes.into_iter().map(|b| b as i8).collect())
    }
    fn u64s(&mut self) -> io::Result<Vec<u64>> {
        let n = self.u64()? as usize;
        (0..n).map(|_| self.u64()).collect()
    }
}

fn strategy_tag(s: LandmarkStrategy) -> (u64, u64) {
    match s {
        LandmarkStrategy::Uniform => (0, 0),
        LandmarkStrategy::HybridDpp { pool_factor } => (1, pool_factor as u64),
        LandmarkStrategy::FullDpp => (2, 0),
    }
}

fn strategy_from_tag(tag: u64, arg: u64) -> io::Result<LandmarkStrategy> {
    match tag {
        0 => Ok(LandmarkStrategy::Uniform),
        1 => Ok(LandmarkStrategy::HybridDpp {
            pool_factor: arg as usize,
        }),
        2 => Ok(LandmarkStrategy::FullDpp),
        _ => Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("bad strategy tag {tag}"),
        )),
    }
}

/// Serialize a model to a writer.
pub fn save<W: Write>(model: &NysHdcModel, w: W) -> io::Result<()> {
    let mut w = Writer { w };
    w.w.write_all(MAGIC)?;
    // Config
    let c = &model.config;
    w.u64(c.hops as u64)?;
    w.u64(c.hv_dim as u64)?;
    w.f64(c.lsh_width)?;
    w.u64(c.num_landmarks as u64)?;
    let (tag, arg) = strategy_tag(c.strategy);
    w.u64(tag)?;
    w.u64(arg)?;
    w.f64(c.mph_gamma)?;
    w.u64(c.pes as u64)?;
    w.u64(c.seed)?;
    // Meta
    w.str(&model.dataset_name)?;
    w.u64(model.num_classes as u64)?;
    w.u64(model.feature_dim as u64)?;
    // LSH
    w.u64(model.lsh.u.len() as u64)?;
    for u in &model.lsh.u {
        w.f64s(u)?;
    }
    w.f64s(&model.lsh.b)?;
    w.f64(model.lsh.w)?;
    // Codebooks
    w.u64(model.codebooks.len() as u64)?;
    for cb in &model.codebooks {
        w.i64s(&cb.codes)?;
    }
    // Landmark hists (CSR)
    w.u64(model.landmark_hists.len() as u64)?;
    for h in &model.landmark_hists {
        w.u64(h.rows as u64)?;
        w.u64(h.cols as u64)?;
        w.usizes(&h.row_ptr)?;
        w.u32s(&h.col_idx)?;
        w.f64s(&h.val)?;
    }
    // Projection
    w.u64(model.projection.d as u64)?;
    w.u64(model.projection.s as u64)?;
    w.u64(model.projection.rank as u64)?;
    w.f32s(&model.projection.data)?;
    // Prototypes (v2: bit-packed, one sign bit per element)
    w.u64(model.packed_prototypes.prototypes.len() as u64)?;
    for p in &model.packed_prototypes.prototypes {
        w.u64(p.dim() as u64)?;
        w.u64s(p.words())?;
    }
    w.usizes(&model.packed_prototypes.counts)?;
    // Landmark indices
    w.usizes(&model.landmark_indices)?;
    Ok(())
}

/// Deserialize a model from a reader, rebuilding MPH lookups, KSE
/// schedule tables and the i8 reference prototypes. Reads both the
/// current packed-prototype format (v2) and the legacy i8 format (v1).
pub fn load<R: Read>(r: R) -> io::Result<NysHdcModel> {
    let mut r = Reader { r };
    let mut magic = [0u8; 8];
    r.r.read_exact(&mut magic)?;
    let version = if &magic == MAGIC {
        2u8
    } else if &magic == MAGIC_V1 {
        1u8
    } else {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            "not a NysX model file",
        ));
    };
    let hops = r.u64()? as usize;
    let hv_dim = r.u64()? as usize;
    let lsh_width = r.f64()?;
    let num_landmarks = r.u64()? as usize;
    let tag = r.u64()?;
    let arg = r.u64()?;
    let strategy = strategy_from_tag(tag, arg)?;
    let mph_gamma = r.f64()?;
    let pes = r.u64()? as usize;
    let seed = r.u64()?;
    let config = ModelConfig {
        hops,
        hv_dim,
        lsh_width,
        num_landmarks,
        strategy,
        mph_gamma,
        pes,
        seed,
    };
    let dataset_name = r.str()?;
    let num_classes = r.u64()? as usize;
    let feature_dim = r.u64()? as usize;
    let n_u = r.u64()? as usize;
    let mut u = Vec::with_capacity(n_u);
    for _ in 0..n_u {
        u.push(r.f64s()?);
    }
    let b = r.f64s()?;
    let w_width = r.f64()?;
    let lsh = LshParams { u, b, w: w_width };
    let n_cb = r.u64()? as usize;
    let codebooks: Vec<Codebook> = (0..n_cb)
        .map(|_| r.i64s().map(Codebook::build))
        .collect::<io::Result<_>>()?;
    let n_h = r.u64()? as usize;
    let mut landmark_hists = Vec::with_capacity(n_h);
    for _ in 0..n_h {
        let rows = r.u64()? as usize;
        let cols = r.u64()? as usize;
        let row_ptr = r.usizes()?;
        let col_idx = r.u32s()?;
        let val = r.f64s()?;
        landmark_hists.push(Csr {
            rows,
            cols,
            row_ptr,
            col_idx,
            val,
        });
    }
    let d = r.u64()? as usize;
    let s = r.u64()? as usize;
    let rank = r.u64()? as usize;
    let data = r.f32s()?;
    if data.len() != d * s {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            "projection size mismatch",
        ));
    }
    let projection = NystromProjection { d, s, data, rank };
    let n_proto = r.u64()? as usize;
    let mut packed_protos = Vec::with_capacity(n_proto);
    for _ in 0..n_proto {
        match version {
            1 => {
                let hv = Hypervector { data: r.i8s()? };
                packed_protos.push(PackedHypervector::pack(&hv));
            }
            _ => {
                let p_dim = r.u64()? as usize;
                if p_dim != hv_dim {
                    return Err(io::Error::new(
                        io::ErrorKind::InvalidData,
                        format!("prototype dim {p_dim} != model hv_dim {hv_dim}"),
                    ));
                }
                let words = r.u64s()?;
                packed_protos.push(PackedHypervector::from_words(p_dim, words).map_err(
                    |e| io::Error::new(io::ErrorKind::InvalidData, format!("prototype: {e}")),
                )?);
            }
        }
    }
    let counts = r.usizes()?;
    let landmark_indices = r.usizes()?;

    // Rebuild derived structures.
    let lookups: Vec<MphLookup> = codebooks
        .iter()
        .map(|cb| {
            let keys: Vec<u64> = cb.codes.iter().map(|&c| code_key(c)).collect();
            let values: Vec<u32> = (0..cb.len() as u32).collect();
            MphLookup::build(&keys, &values, mph_gamma)
        })
        .collect();
    let kse_schedules = NysHdcModel::build_kse_schedules(&landmark_hists, pes);
    let packed_prototypes = PackedPrototypes {
        prototypes: packed_protos,
        counts,
    };
    let prototypes: ClassPrototypes = packed_prototypes.to_reference();

    Ok(NysHdcModel {
        config,
        dataset_name,
        num_classes,
        feature_dim,
        lsh,
        codebooks,
        lookups,
        landmark_hists,
        kse_schedules,
        projection,
        prototypes,
        packed_prototypes,
        landmark_indices,
    })
}

/// Save to a file path.
pub fn save_file(model: &NysHdcModel, path: &std::path::Path) -> io::Result<()> {
    let f = std::fs::File::create(path)?;
    save(model, std::io::BufWriter::new(f))
}

/// Load from a file path.
pub fn load_file(path: &std::path::Path) -> io::Result<NysHdcModel> {
    let f = std::fs::File::open(path)?;
    load(std::io::BufReader::new(f))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::tudataset::spec_by_name;
    use crate::model::train::{encode_hv, train};
    use crate::model::ModelConfig;

    /// The legacy v1 writer (i8 prototypes), kept test-only to prove the
    /// reader's backwards compatibility.
    fn save_v1<W: Write>(model: &NysHdcModel, w: W) -> io::Result<()> {
        let mut w = Writer { w };
        w.w.write_all(MAGIC_V1)?;
        let c = &model.config;
        w.u64(c.hops as u64)?;
        w.u64(c.hv_dim as u64)?;
        w.f64(c.lsh_width)?;
        w.u64(c.num_landmarks as u64)?;
        let (tag, arg) = strategy_tag(c.strategy);
        w.u64(tag)?;
        w.u64(arg)?;
        w.f64(c.mph_gamma)?;
        w.u64(c.pes as u64)?;
        w.u64(c.seed)?;
        w.str(&model.dataset_name)?;
        w.u64(model.num_classes as u64)?;
        w.u64(model.feature_dim as u64)?;
        w.u64(model.lsh.u.len() as u64)?;
        for u in &model.lsh.u {
            w.f64s(u)?;
        }
        w.f64s(&model.lsh.b)?;
        w.f64(model.lsh.w)?;
        w.u64(model.codebooks.len() as u64)?;
        for cb in &model.codebooks {
            w.i64s(&cb.codes)?;
        }
        w.u64(model.landmark_hists.len() as u64)?;
        for h in &model.landmark_hists {
            w.u64(h.rows as u64)?;
            w.u64(h.cols as u64)?;
            w.usizes(&h.row_ptr)?;
            w.u32s(&h.col_idx)?;
            w.f64s(&h.val)?;
        }
        w.u64(model.projection.d as u64)?;
        w.u64(model.projection.s as u64)?;
        w.u64(model.projection.rank as u64)?;
        w.f32s(&model.projection.data)?;
        w.u64(model.prototypes.prototypes.len() as u64)?;
        for p in &model.prototypes.prototypes {
            let bytes: Vec<u8> = p.data.iter().map(|&x| x as u8).collect();
            w.bytes(&bytes)?;
        }
        w.usizes(&model.prototypes.counts)?;
        w.usizes(&model.landmark_indices)?;
        Ok(())
    }

    #[test]
    fn roundtrip_preserves_behaviour() {
        let spec = spec_by_name("MUTAG").unwrap();
        let (ds, _, _) = spec.generate_scaled(5, 0.2);
        let cfg = ModelConfig {
            hops: 2,
            hv_dim: 512,
            num_landmarks: 8,
            ..ModelConfig::default()
        };
        let model = train(&ds, &cfg);
        let mut buf = Vec::new();
        save(&model, &mut buf).unwrap();
        let back = load(&buf[..]).unwrap();
        assert_eq!(back.dataset_name, model.dataset_name);
        assert_eq!(back.landmark_indices, model.landmark_indices);
        assert_eq!(back.projection.data, model.projection.data);
        assert_eq!(back.prototypes.prototypes, model.prototypes.prototypes);
        assert_eq!(back.packed_prototypes, model.packed_prototypes);
        // Behavioural equality: same HV for the same query.
        for (g, _) in ds.test.iter().take(5) {
            assert_eq!(encode_hv(&model, g), encode_hv(&back, g));
        }
        // Rebuilt MPH agrees with stored codebooks.
        for t in 0..2 {
            for &c in &back.codebooks[t].codes {
                assert_eq!(
                    back.lookups[t].get(crate::mph::code_key(c)),
                    back.codebooks[t].index_of(c)
                );
            }
        }
    }

    #[test]
    fn v1_files_still_load() {
        let spec = spec_by_name("MUTAG").unwrap();
        let (ds, _, _) = spec.generate_scaled(7, 0.2);
        let cfg = ModelConfig {
            hops: 2,
            // Off a word boundary so the packed conversion's tail path is
            // exercised by the version shim too.
            hv_dim: 500,
            num_landmarks: 8,
            ..ModelConfig::default()
        };
        let model = train(&ds, &cfg);
        let mut v1 = Vec::new();
        save_v1(&model, &mut v1).unwrap();
        let back = load(&v1[..]).unwrap();
        assert_eq!(back.prototypes.prototypes, model.prototypes.prototypes);
        assert_eq!(back.packed_prototypes, model.packed_prototypes);
        for (g, _) in ds.test.iter().take(3) {
            assert_eq!(encode_hv(&model, g), encode_hv(&back, g));
        }
    }

    #[test]
    fn v2_prototype_section_is_packed_smaller() {
        let spec = spec_by_name("MUTAG").unwrap();
        let (ds, _, _) = spec.generate_scaled(8, 0.15);
        let cfg = ModelConfig {
            hops: 2,
            hv_dim: 4096,
            num_landmarks: 6,
            ..ModelConfig::default()
        };
        let model = train(&ds, &cfg);
        let (mut v1, mut v2) = (Vec::new(), Vec::new());
        save_v1(&model, &mut v1).unwrap();
        save(&model, &mut v2).unwrap();
        // i8 protos: C*d bytes; packed: C*d/8 (+ small headers).
        let c = model.num_classes;
        let d = model.d();
        let saved = v1.len() - v2.len();
        let expect = c * d - c * (d / 8 + 8); // minus per-proto dim header
        assert!(
            saved >= expect - 64 && v2.len() < v1.len(),
            "saved {saved} bytes, expected ≈{expect}"
        );
    }

    #[test]
    fn rejects_bad_magic() {
        let buf = b"NOTAMODELxxxxxxxxxxxxxxx".to_vec();
        assert!(load(&buf[..]).is_err());
    }

    #[test]
    fn rejects_truncated() {
        let spec = spec_by_name("MUTAG").unwrap();
        let (ds, _, _) = spec.generate_scaled(6, 0.15);
        let cfg = ModelConfig {
            hops: 2,
            hv_dim: 128,
            num_landmarks: 5,
            ..ModelConfig::default()
        };
        let model = train(&ds, &cfg);
        let mut buf = Vec::new();
        save(&model, &mut buf).unwrap();
        buf.truncate(buf.len() / 2);
        assert!(load(&buf[..]).is_err());
    }
}
