//! Binary model serialization (no `serde` available — a small
//! length-prefixed little-endian format with magic/version header).
//!
//! Derived structures (MPH lookups, KSE schedule tables) are *rebuilt*
//! on load: they are deterministic functions of the stored codebooks /
//! histogram matrices, which keeps the artifact compact and guarantees
//! the offline tables always match the deployed parameters.

use std::io::{self, Read, Write};

use super::{ModelConfig, NysHdcModel};
use crate::hdc::{ClassPrototypes, Hypervector};
use crate::kernel::{Codebook, LshParams};
use crate::mph::{code_key, MphLookup};
use crate::nystrom::{LandmarkStrategy, NystromProjection};
use crate::sparse::Csr;

const MAGIC: &[u8; 8] = b"NYSXMDL\x01";

struct Writer<W: Write> {
    w: W,
}

impl<W: Write> Writer<W> {
    fn u64(&mut self, v: u64) -> io::Result<()> {
        self.w.write_all(&v.to_le_bytes())
    }
    fn i64(&mut self, v: i64) -> io::Result<()> {
        self.w.write_all(&v.to_le_bytes())
    }
    fn f64(&mut self, v: f64) -> io::Result<()> {
        self.w.write_all(&v.to_le_bytes())
    }
    fn bytes(&mut self, v: &[u8]) -> io::Result<()> {
        self.u64(v.len() as u64)?;
        self.w.write_all(v)
    }
    fn str(&mut self, s: &str) -> io::Result<()> {
        self.bytes(s.as_bytes())
    }
    fn f64s(&mut self, v: &[f64]) -> io::Result<()> {
        self.u64(v.len() as u64)?;
        for &x in v {
            self.f64(x)?;
        }
        Ok(())
    }
    fn f32s(&mut self, v: &[f32]) -> io::Result<()> {
        self.u64(v.len() as u64)?;
        for &x in v {
            self.w.write_all(&x.to_le_bytes())?;
        }
        Ok(())
    }
    fn i64s(&mut self, v: &[i64]) -> io::Result<()> {
        self.u64(v.len() as u64)?;
        for &x in v {
            self.i64(x)?;
        }
        Ok(())
    }
    fn usizes(&mut self, v: &[usize]) -> io::Result<()> {
        self.u64(v.len() as u64)?;
        for &x in v {
            self.u64(x as u64)?;
        }
        Ok(())
    }
    fn u32s(&mut self, v: &[u32]) -> io::Result<()> {
        self.u64(v.len() as u64)?;
        for &x in v {
            self.w.write_all(&x.to_le_bytes())?;
        }
        Ok(())
    }
    fn i8s(&mut self, v: &[i8]) -> io::Result<()> {
        self.u64(v.len() as u64)?;
        let bytes: Vec<u8> = v.iter().map(|&x| x as u8).collect();
        self.w.write_all(&bytes)
    }
}

struct Reader<R: Read> {
    r: R,
}

impl<R: Read> Reader<R> {
    fn u64(&mut self) -> io::Result<u64> {
        let mut b = [0u8; 8];
        self.r.read_exact(&mut b)?;
        Ok(u64::from_le_bytes(b))
    }
    fn i64(&mut self) -> io::Result<i64> {
        let mut b = [0u8; 8];
        self.r.read_exact(&mut b)?;
        Ok(i64::from_le_bytes(b))
    }
    fn f64(&mut self) -> io::Result<f64> {
        let mut b = [0u8; 8];
        self.r.read_exact(&mut b)?;
        Ok(f64::from_le_bytes(b))
    }
    fn bytes(&mut self) -> io::Result<Vec<u8>> {
        let n = self.u64()? as usize;
        let mut v = vec![0u8; n];
        self.r.read_exact(&mut v)?;
        Ok(v)
    }
    fn str(&mut self) -> io::Result<String> {
        String::from_utf8(self.bytes()?)
            .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e))
    }
    fn f64s(&mut self) -> io::Result<Vec<f64>> {
        let n = self.u64()? as usize;
        (0..n).map(|_| self.f64()).collect()
    }
    fn f32s(&mut self) -> io::Result<Vec<f32>> {
        let n = self.u64()? as usize;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let mut b = [0u8; 4];
            self.r.read_exact(&mut b)?;
            out.push(f32::from_le_bytes(b));
        }
        Ok(out)
    }
    fn i64s(&mut self) -> io::Result<Vec<i64>> {
        let n = self.u64()? as usize;
        (0..n).map(|_| self.i64()).collect()
    }
    fn usizes(&mut self) -> io::Result<Vec<usize>> {
        let n = self.u64()? as usize;
        (0..n).map(|_| self.u64().map(|v| v as usize)).collect()
    }
    fn u32s(&mut self) -> io::Result<Vec<u32>> {
        let n = self.u64()? as usize;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let mut b = [0u8; 4];
            self.r.read_exact(&mut b)?;
            out.push(u32::from_le_bytes(b));
        }
        Ok(out)
    }
    fn i8s(&mut self) -> io::Result<Vec<i8>> {
        let bytes = self.bytes()?;
        Ok(bytes.into_iter().map(|b| b as i8).collect())
    }
}

fn strategy_tag(s: LandmarkStrategy) -> (u64, u64) {
    match s {
        LandmarkStrategy::Uniform => (0, 0),
        LandmarkStrategy::HybridDpp { pool_factor } => (1, pool_factor as u64),
        LandmarkStrategy::FullDpp => (2, 0),
    }
}

fn strategy_from_tag(tag: u64, arg: u64) -> io::Result<LandmarkStrategy> {
    match tag {
        0 => Ok(LandmarkStrategy::Uniform),
        1 => Ok(LandmarkStrategy::HybridDpp {
            pool_factor: arg as usize,
        }),
        2 => Ok(LandmarkStrategy::FullDpp),
        _ => Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("bad strategy tag {tag}"),
        )),
    }
}

/// Serialize a model to a writer.
pub fn save<W: Write>(model: &NysHdcModel, w: W) -> io::Result<()> {
    let mut w = Writer { w };
    w.w.write_all(MAGIC)?;
    // Config
    let c = &model.config;
    w.u64(c.hops as u64)?;
    w.u64(c.hv_dim as u64)?;
    w.f64(c.lsh_width)?;
    w.u64(c.num_landmarks as u64)?;
    let (tag, arg) = strategy_tag(c.strategy);
    w.u64(tag)?;
    w.u64(arg)?;
    w.f64(c.mph_gamma)?;
    w.u64(c.pes as u64)?;
    w.u64(c.seed)?;
    // Meta
    w.str(&model.dataset_name)?;
    w.u64(model.num_classes as u64)?;
    w.u64(model.feature_dim as u64)?;
    // LSH
    w.u64(model.lsh.u.len() as u64)?;
    for u in &model.lsh.u {
        w.f64s(u)?;
    }
    w.f64s(&model.lsh.b)?;
    w.f64(model.lsh.w)?;
    // Codebooks
    w.u64(model.codebooks.len() as u64)?;
    for cb in &model.codebooks {
        w.i64s(&cb.codes)?;
    }
    // Landmark hists (CSR)
    w.u64(model.landmark_hists.len() as u64)?;
    for h in &model.landmark_hists {
        w.u64(h.rows as u64)?;
        w.u64(h.cols as u64)?;
        w.usizes(&h.row_ptr)?;
        w.u32s(&h.col_idx)?;
        w.f64s(&h.val)?;
    }
    // Projection
    w.u64(model.projection.d as u64)?;
    w.u64(model.projection.s as u64)?;
    w.u64(model.projection.rank as u64)?;
    w.f32s(&model.projection.data)?;
    // Prototypes
    w.u64(model.prototypes.prototypes.len() as u64)?;
    for p in &model.prototypes.prototypes {
        w.i8s(&p.data)?;
    }
    w.usizes(&model.prototypes.counts)?;
    // Landmark indices
    w.usizes(&model.landmark_indices)?;
    Ok(())
}

/// Deserialize a model from a reader, rebuilding MPH lookups and KSE
/// schedule tables.
pub fn load<R: Read>(r: R) -> io::Result<NysHdcModel> {
    let mut r = Reader { r };
    let mut magic = [0u8; 8];
    r.r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            "not a NysX model file",
        ));
    }
    let hops = r.u64()? as usize;
    let hv_dim = r.u64()? as usize;
    let lsh_width = r.f64()?;
    let num_landmarks = r.u64()? as usize;
    let tag = r.u64()?;
    let arg = r.u64()?;
    let strategy = strategy_from_tag(tag, arg)?;
    let mph_gamma = r.f64()?;
    let pes = r.u64()? as usize;
    let seed = r.u64()?;
    let config = ModelConfig {
        hops,
        hv_dim,
        lsh_width,
        num_landmarks,
        strategy,
        mph_gamma,
        pes,
        seed,
    };
    let dataset_name = r.str()?;
    let num_classes = r.u64()? as usize;
    let feature_dim = r.u64()? as usize;
    let n_u = r.u64()? as usize;
    let mut u = Vec::with_capacity(n_u);
    for _ in 0..n_u {
        u.push(r.f64s()?);
    }
    let b = r.f64s()?;
    let w_width = r.f64()?;
    let lsh = LshParams { u, b, w: w_width };
    let n_cb = r.u64()? as usize;
    let codebooks: Vec<Codebook> = (0..n_cb)
        .map(|_| r.i64s().map(Codebook::build))
        .collect::<io::Result<_>>()?;
    let n_h = r.u64()? as usize;
    let mut landmark_hists = Vec::with_capacity(n_h);
    for _ in 0..n_h {
        let rows = r.u64()? as usize;
        let cols = r.u64()? as usize;
        let row_ptr = r.usizes()?;
        let col_idx = r.u32s()?;
        let val = r.f64s()?;
        landmark_hists.push(Csr {
            rows,
            cols,
            row_ptr,
            col_idx,
            val,
        });
    }
    let d = r.u64()? as usize;
    let s = r.u64()? as usize;
    let rank = r.u64()? as usize;
    let data = r.f32s()?;
    if data.len() != d * s {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            "projection size mismatch",
        ));
    }
    let projection = NystromProjection { d, s, data, rank };
    let n_proto = r.u64()? as usize;
    let mut prototypes = Vec::with_capacity(n_proto);
    for _ in 0..n_proto {
        prototypes.push(Hypervector { data: r.i8s()? });
    }
    let counts = r.usizes()?;
    let landmark_indices = r.usizes()?;

    // Rebuild derived structures.
    let lookups: Vec<MphLookup> = codebooks
        .iter()
        .map(|cb| {
            let keys: Vec<u64> = cb.codes.iter().map(|&c| code_key(c)).collect();
            let values: Vec<u32> = (0..cb.len() as u32).collect();
            MphLookup::build(&keys, &values, mph_gamma)
        })
        .collect();
    let kse_schedules = NysHdcModel::build_kse_schedules(&landmark_hists, pes);

    Ok(NysHdcModel {
        config,
        dataset_name,
        num_classes,
        feature_dim,
        lsh,
        codebooks,
        lookups,
        landmark_hists,
        kse_schedules,
        projection,
        prototypes: ClassPrototypes {
            prototypes,
            counts,
        },
        landmark_indices,
    })
}

/// Save to a file path.
pub fn save_file(model: &NysHdcModel, path: &std::path::Path) -> io::Result<()> {
    let f = std::fs::File::create(path)?;
    save(model, std::io::BufWriter::new(f))
}

/// Load from a file path.
pub fn load_file(path: &std::path::Path) -> io::Result<NysHdcModel> {
    let f = std::fs::File::open(path)?;
    load(std::io::BufReader::new(f))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::tudataset::spec_by_name;
    use crate::model::train::{encode_hv, train};
    use crate::model::ModelConfig;

    #[test]
    fn roundtrip_preserves_behaviour() {
        let spec = spec_by_name("MUTAG").unwrap();
        let (ds, _, _) = spec.generate_scaled(5, 0.2);
        let cfg = ModelConfig {
            hops: 2,
            hv_dim: 512,
            num_landmarks: 8,
            ..ModelConfig::default()
        };
        let model = train(&ds, &cfg);
        let mut buf = Vec::new();
        save(&model, &mut buf).unwrap();
        let back = load(&buf[..]).unwrap();
        assert_eq!(back.dataset_name, model.dataset_name);
        assert_eq!(back.landmark_indices, model.landmark_indices);
        assert_eq!(back.projection.data, model.projection.data);
        assert_eq!(back.prototypes.prototypes, model.prototypes.prototypes);
        // Behavioural equality: same HV for the same query.
        for (g, _) in ds.test.iter().take(5) {
            assert_eq!(encode_hv(&model, g), encode_hv(&back, g));
        }
        // Rebuilt MPH agrees with stored codebooks.
        for t in 0..2 {
            for &c in &back.codebooks[t].codes {
                assert_eq!(
                    back.lookups[t].get(crate::mph::code_key(c)),
                    back.codebooks[t].index_of(c)
                );
            }
        }
    }

    #[test]
    fn rejects_bad_magic() {
        let buf = b"NOTAMODELxxxxxxxxxxxxxxx".to_vec();
        assert!(load(&buf[..]).is_err());
    }

    #[test]
    fn rejects_truncated() {
        let spec = spec_by_name("MUTAG").unwrap();
        let (ds, _, _) = spec.generate_scaled(6, 0.15);
        let cfg = ModelConfig {
            hops: 2,
            hv_dim: 128,
            num_landmarks: 5,
            ..ModelConfig::default()
        };
        let model = train(&ds, &cfg);
        let mut buf = Vec::new();
        save(&model, &mut buf).unwrap();
        buf.truncate(buf.len() / 2);
        assert!(load(&buf[..]).is_err());
    }
}
