//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the rust hot path via
//! the `xla` crate (PJRT CPU client). Python never runs at inference
//! time — the interchange is HLO text (see /opt/xla-example/README.md for
//! why text, not serialized protos).
//!
//! The manifest layer below is dependency-free and always compiled; the
//! executing layer ([`PjrtRuntime`], [`XlaNee`], [`XlaEncoder`]) needs
//! the external `xla` + `anyhow` crates and is gated behind the
//! `xla-runtime` cargo feature (off by default — the crates are not in
//! the vendored set).

use std::io;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

#[cfg(feature = "xla-runtime")]
mod pjrt;
#[cfg(feature = "xla-runtime")]
pub use pjrt::{PjrtRuntime, XlaEncoder, XlaNee};

fn invalid_data(msg: String) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg)
}

/// A parsed `artifacts/manifest.json` entry.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub kind: String,
    pub path: PathBuf,
    pub dims: std::collections::BTreeMap<String, usize>,
}

/// The artifact manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactEntry>,
}

impl Manifest {
    pub fn load(dir: &Path) -> io::Result<Self> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        let doc = Json::parse(&text).map_err(|e| invalid_data(format!("manifest parse: {e}")))?;
        let mut entries = Vec::new();
        for item in doc
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| invalid_data("manifest missing artifacts array".into()))?
        {
            let mut dims = std::collections::BTreeMap::new();
            if let Json::Obj(map) = item {
                for (k, v) in map {
                    if let Some(x) = v.as_f64() {
                        dims.insert(k.clone(), x as usize);
                    }
                }
            }
            entries.push(ArtifactEntry {
                name: item
                    .get("name")
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| invalid_data("artifact missing name".into()))?
                    .to_string(),
                kind: item
                    .get("kind")
                    .and_then(|v| v.as_str())
                    .unwrap_or("")
                    .to_string(),
                path: dir.join(
                    item.get("path")
                        .and_then(|v| v.as_str())
                        .ok_or_else(|| invalid_data("artifact missing path".into()))?,
                ),
                dims,
            });
        }
        Ok(Self {
            dir: dir.to_path_buf(),
            entries,
        })
    }

    /// Find the smallest NEE artifact with matching `d` and `s_art >= s`.
    pub fn find_nee(&self, d: usize, s: usize) -> Option<&ArtifactEntry> {
        self.entries
            .iter()
            .filter(|e| {
                e.kind == "nee"
                    && e.dims.get("d") == Some(&d)
                    && e.dims.get("s").is_some_and(|&sa| sa >= s)
            })
            .min_by_key(|e| e.dims["s"])
    }

    /// Find an encode artifact able to hold the given padded dims.
    pub fn find_encode(
        &self,
        n: usize,
        f: usize,
        hops: usize,
        bmax: usize,
        s: usize,
        d: usize,
        classes: usize,
    ) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| {
            e.kind == "encode"
                && e.dims.get("n").is_some_and(|&v| v >= n)
                && e.dims.get("f") == Some(&f)
                && e.dims.get("hops") == Some(&hops)
                && e.dims.get("bmax").is_some_and(|&v| v >= bmax)
                && e.dims.get("s").is_some_and(|&v| v >= s)
                && e.dims.get("d") == Some(&d)
                && e.dims.get("classes").is_some_and(|&v| v >= classes)
        })
    }
}
