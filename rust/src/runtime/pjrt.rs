//! The executing half of the runtime: PJRT CPU client, artifact
//! compilation and the XLA-backed NEE / full-encoder wrappers. Compiled
//! only with the `xla-runtime` feature (requires the external `xla` and
//! `anyhow` crates).

use std::path::Path;

use anyhow::{anyhow, bail, Result};

use super::Manifest;
use crate::graph::Graph;
use crate::model::NysHdcModel;

/// The PJRT CPU runtime.
pub struct PjrtRuntime {
    pub client: xla::PjRtClient,
}

impl PjrtRuntime {
    pub fn cpu() -> Result<Self> {
        Ok(Self {
            client: xla::PjRtClient::cpu()?,
        })
    }

    /// Load + compile an HLO-text artifact.
    pub fn compile_artifact(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        Ok(self.client.compile(&comp)?)
    }
}

fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// The XLA-backed NEE: executes `sign(P_nys C)` through the AOT artifact,
/// with `P_nys` zero-padded to the artifact's `s` and kept as a
/// pre-staged literal (the DDR-resident matrix of the paper).
pub struct XlaNee {
    exe: xla::PjRtLoadedExecutable,
    p_literal: xla::Literal,
    pub d: usize,
    pub s_model: usize,
    pub s_artifact: usize,
}

impl XlaNee {
    pub fn new(rt: &PjrtRuntime, manifest: &Manifest, model: &NysHdcModel) -> Result<Self> {
        let d = model.d();
        let s = model.s();
        let entry = manifest
            .find_nee(d, s)
            .ok_or_else(|| anyhow!("no NEE artifact for d={d}, s>={s}"))?;
        let s_art = entry.dims["s"];
        let exe = rt.compile_artifact(&entry.path)?;
        // Zero-pad P_nys columns [s, s_art).
        let mut padded = vec![0.0f32; d * s_art];
        for r in 0..d {
            padded[r * s_art..r * s_art + s].copy_from_slice(model.projection.row(r));
        }
        let p_literal = literal_f32(&padded, &[d as i64, s_art as i64])?;
        Ok(Self {
            exe,
            p_literal,
            d,
            s_model: s,
            s_artifact: s_art,
        })
    }

    /// h = sign(P_nys C) — returns the bipolar HV as f32 ±1.
    pub fn project_sign(&self, c: &[f64]) -> Result<Vec<f32>> {
        if c.len() != self.s_model {
            bail!("C length {} != model s {}", c.len(), self.s_model);
        }
        let mut c_pad = vec![0.0f32; self.s_artifact];
        for (dst, &src) in c_pad.iter_mut().zip(c.iter()) {
            *dst = src as f32;
        }
        let c_lit = xla::Literal::vec1(&c_pad);
        let result = self.exe.execute::<&xla::Literal>(&[&self.p_literal, &c_lit])?[0][0]
            .to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}

/// The XLA-backed full encoder: executes the whole Algorithm-1 graph
/// (L2 export) for cross-layer equivalence testing and small-graph
/// serving. Model parameters are packed once; per query only the padded
/// (A, F, mask) change.
pub struct XlaEncoder {
    exe: xla::PjRtLoadedExecutable,
    params: Vec<xla::Literal>,
    pub n_max: usize,
    pub f: usize,
    pub hops: usize,
    pub bmax: usize,
    pub s_art: usize,
    pub d: usize,
    pub classes_art: usize,
    pub num_classes: usize,
}

impl XlaEncoder {
    pub fn new(rt: &PjrtRuntime, manifest: &Manifest, model: &NysHdcModel) -> Result<Self> {
        let hops = model.hops();
        let f = model.feature_dim;
        let bmax_needed = model.codebooks.iter().map(|c| c.len()).max().unwrap_or(0);
        let entry = manifest
            .find_encode(
                1,
                f,
                hops,
                bmax_needed,
                model.s(),
                model.d(),
                model.num_classes,
            )
            .ok_or_else(|| {
                anyhow!(
                    "no encode artifact for f={f} hops={hops} bmax>={bmax_needed} s>={} d={} c>={}",
                    model.s(),
                    model.d(),
                    model.num_classes
                )
            })?;
        let (n_max, bmax, s_art, d, classes_art) = (
            entry.dims["n"],
            entry.dims["bmax"],
            entry.dims["s"],
            entry.dims["d"],
            entry.dims["classes"],
        );
        let exe = rt.compile_artifact(&entry.path)?;

        // --- pack model parameters (padded) ---
        let mut params = Vec::new();
        // u: (hops, f)
        let u_flat: Vec<f32> = model
            .lsh
            .u
            .iter()
            .flat_map(|u| u.iter().map(|&x| x as f32))
            .collect();
        params.push(literal_f32(&u_flat, &[hops as i64, f as i64])?);
        // b: (hops,)
        let b_flat: Vec<f32> = model.lsh.b.iter().map(|&x| x as f32).collect();
        params.push(xla::Literal::vec1(&b_flat));
        // w: ()
        params.push(xla::Literal::scalar(model.lsh.w as f32));
        // codebooks: (hops, bmax) i32, sentinel-padded.
        let mut cb = vec![i32::MAX; hops * bmax];
        for (t, book) in model.codebooks.iter().enumerate() {
            for (i, &code) in book.codes.iter().enumerate() {
                cb[t * bmax + i] = i32::try_from(code)
                    .map_err(|_| anyhow!("LSH code {code} exceeds i32 (hop {t})"))?;
            }
        }
        params.push(xla::Literal::vec1(&cb).reshape(&[hops as i64, bmax as i64])?);
        // hists: (hops, s_art, bmax)
        let mut hists = vec![0.0f32; hops * s_art * bmax];
        for (t, h) in model.landmark_hists.iter().enumerate() {
            for r in 0..h.rows {
                for k in h.row_range(r) {
                    let cidx = h.col_idx[k] as usize;
                    hists[t * s_art * bmax + r * bmax + cidx] = h.val[k] as f32;
                }
            }
        }
        params.push(literal_f32(
            &hists,
            &[hops as i64, s_art as i64, bmax as i64],
        )?);
        // p_nys: (d, s_art)
        let mut p = vec![0.0f32; d * s_art];
        for r in 0..d {
            p[r * s_art..r * s_art + model.s()].copy_from_slice(model.projection.row(r));
        }
        params.push(literal_f32(&p, &[d as i64, s_art as i64])?);
        // protos: (classes_art, d) — padded classes get all -1 rows with
        // score strictly below any real class only if real scores are
        // higher; we guard by taking argmax over real classes on the rust
        // side anyway.
        let mut g = vec![0.0f32; classes_art * d];
        let protos = model.reference_prototypes();
        for (ci, proto) in protos.prototypes.iter().enumerate() {
            for (j, &v) in proto.data.iter().enumerate() {
                g[ci * d + j] = v as f32;
            }
        }
        params.push(literal_f32(&g, &[classes_art as i64, d as i64])?);

        Ok(Self {
            exe,
            params,
            n_max,
            f,
            hops,
            bmax,
            s_art,
            d,
            classes_art,
            num_classes: model.num_classes,
        })
    }

    /// Can this artifact hold the graph?
    pub fn fits(&self, graph: &Graph) -> bool {
        graph.num_nodes() <= self.n_max && graph.feature_dim() == self.f
    }

    /// Run Algorithm 1 through XLA: returns (predicted, scores, hv±1).
    pub fn encode_classify(&self, graph: &Graph) -> Result<(usize, Vec<f32>, Vec<f32>)> {
        if !self.fits(graph) {
            bail!(
                "graph ({} nodes, f={}) exceeds artifact (n_max={}, f={})",
                graph.num_nodes(),
                graph.feature_dim(),
                self.n_max,
                self.f
            );
        }
        let n = self.n_max;
        let real = graph.num_nodes();
        // A padded dense.
        let mut adj = vec![0.0f32; n * n];
        for r in 0..real {
            for k in graph.adj.row_range(r) {
                adj[r * n + graph.adj.col_idx[k] as usize] = 1.0;
            }
        }
        let mut feats = vec![0.0f32; n * self.f];
        for r in 0..real {
            for (j, &v) in graph.features.row(r).iter().enumerate() {
                feats[r * self.f + j] = v as f32;
            }
        }
        let mut mask = vec![0.0f32; n];
        mask[..real].iter_mut().for_each(|m| *m = 1.0);

        let a_lit = literal_f32(&adj, &[n as i64, n as i64])?;
        let f_lit = literal_f32(&feats, &[n as i64, self.f as i64])?;
        let m_lit = xla::Literal::vec1(&mask);

        let mut args: Vec<&xla::Literal> = vec![&a_lit, &f_lit, &m_lit];
        args.extend(self.params.iter());
        let result = self.exe.execute::<&xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let (scores_lit, hv_lit) = result.to_tuple2()?;
        let scores = scores_lit.to_vec::<f32>()?;
        let hv = hv_lit.to_vec::<f32>()?;
        // Argmax over REAL classes only.
        let mut best = 0usize;
        for c in 0..self.num_classes {
            if scores[c] > scores[best] {
                best = c;
            }
        }
        Ok((best, scores, hv))
    }
}
