//! Hyperdimensional computing core (paper §2.1.1): bipolar hypervectors,
//! the bundling/binding/permutation operators, similarity metrics and
//! class prototypes.
//!
//! Two representations coexist: [`Hypervector`] (`Vec<i8>`, the readable
//! reference/oracle) and [`packed::PackedHypervector`] (one sign bit per
//! element, the deployed hot-path representation). They are lossless
//! converses of each other and every operator pair is property-tested
//! bit-identical.
//!
//! # SIMD backends
//!
//! The packed hot kernels (XOR+popcount matching, the carry-save bundle
//! counters) run on a runtime-dispatched [`simd::PopcountBackend`]:
//! scalar (the oracle), AVX2 on x86_64 (when detected at startup), NEON
//! on aarch64. The backend is chosen once per process by
//! [`simd::active`]; `NYSX_FORCE_SCALAR=1` pins the scalar oracle for
//! differential testing, and the `*_with` kernel variants accept an
//! explicit backend so tests and benches can compare them side by side.
//! All backends are property-tested bit-identical to scalar — and scalar
//! to the i8 reference — so dispatch never changes results.

pub mod packed;
pub mod prototypes;
pub mod simd;

pub use packed::{
    packed_bundle, PackedAccumulator, PackedBatch, PackedHypervector, PackedPrototypes,
};
pub use prototypes::{ClassPrototypes, PrototypeAccumulator};
pub use simd::PopcountBackend;

/// A bipolar hypervector h ∈ {-1, +1}^d stored as i8 (the accelerator's
/// SCE consumes sign bits; i8 keeps the functional model simple and
/// cache-dense).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Hypervector {
    pub data: Vec<i8>,
}

impl Hypervector {
    pub fn dim(&self) -> usize {
        self.data.len()
    }

    /// Bipolarize a real vector: h = sign(y) with sign(0) := +1 (matches
    /// the convention in the jax reference kernel).
    pub fn from_real(y: &[f64]) -> Self {
        Self {
            data: y.iter().map(|&v| if v < 0.0 { -1i8 } else { 1i8 }).collect(),
        }
    }

    pub fn from_real_f32(y: &[f32]) -> Self {
        Self {
            data: y.iter().map(|&v| if v < 0.0 { -1i8 } else { 1i8 }).collect(),
        }
    }

    /// Random bipolar HV.
    pub fn random(d: usize, rng: &mut crate::util::rng::Xoshiro256) -> Self {
        Self {
            data: (0..d).map(|_| if rng.bernoulli(0.5) { 1 } else { -1 }).collect(),
        }
    }

    /// Binding (⊗): element-wise product. Produces an HV dissimilar to
    /// both inputs.
    pub fn bind(&self, other: &Hypervector) -> Hypervector {
        assert_eq!(self.dim(), other.dim());
        Hypervector {
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(&a, &b)| a * b)
                .collect(),
        }
    }

    /// Permutation (ρ^i): cyclic shift by i positions.
    pub fn permute(&self, i: usize) -> Hypervector {
        let d = self.dim();
        if d == 0 {
            return self.clone();
        }
        let shift = i % d;
        let mut data = Vec::with_capacity(d);
        data.extend_from_slice(&self.data[d - shift..]);
        data.extend_from_slice(&self.data[..d - shift]);
        Hypervector { data }
    }

    /// Dot-product similarity (integer); equals d - 2*hamming for bipolar.
    pub fn dot(&self, other: &Hypervector) -> i64 {
        assert_eq!(self.dim(), other.dim());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| (a as i64) * (b as i64))
            .sum()
    }

    /// Cosine similarity in [-1, 1].
    pub fn cosine(&self, other: &Hypervector) -> f64 {
        if self.dim() == 0 {
            return 0.0;
        }
        self.dot(other) as f64 / self.dim() as f64
    }

    /// Pack into the 1-bit-per-element representation (lossless for
    /// bipolar data; see [`packed::PackedHypervector`]).
    pub fn pack(&self) -> PackedHypervector {
        PackedHypervector::pack(self)
    }

    /// Hamming distance (number of disagreeing coordinates).
    pub fn hamming(&self, other: &Hypervector) -> usize {
        self.data
            .iter()
            .zip(&other.data)
            .filter(|(&a, &b)| a != b)
            .count()
    }
}

/// Bundling (⊕) of many HVs: element-wise sum then sign. Ties (sum == 0)
/// break to +1.
pub fn bundle(hvs: &[&Hypervector]) -> Hypervector {
    assert!(!hvs.is_empty(), "bundle of nothing");
    let d = hvs[0].dim();
    let mut acc = vec![0i64; d];
    for hv in hvs {
        assert_eq!(hv.dim(), d);
        for (a, &b) in acc.iter_mut().zip(&hv.data) {
            *a += b as i64;
        }
    }
    Hypervector {
        data: acc.iter().map(|&v| if v < 0 { -1 } else { 1 }).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn sign_convention() {
        let h = Hypervector::from_real(&[-0.5, 0.0, 2.0]);
        assert_eq!(h.data, vec![-1, 1, 1]);
    }

    #[test]
    fn random_hvs_quasi_orthogonal() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        let a = Hypervector::random(10_000, &mut rng);
        let b = Hypervector::random(10_000, &mut rng);
        assert!((a.cosine(&a) - 1.0).abs() < 1e-12);
        assert!(a.cosine(&b).abs() < 0.05, "cos={}", a.cosine(&b));
    }

    #[test]
    fn binding_dissimilar_and_invertible() {
        let mut rng = Xoshiro256::seed_from_u64(2);
        let a = Hypervector::random(10_000, &mut rng);
        let b = Hypervector::random(10_000, &mut rng);
        let c = a.bind(&b);
        assert!(c.cosine(&a).abs() < 0.05);
        assert!(c.cosine(&b).abs() < 0.05);
        // Self-inverse: (a⊗b)⊗b == a
        assert_eq!(c.bind(&b), a);
    }

    #[test]
    fn permute_cyclic_group() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        let a = Hypervector::random(257, &mut rng);
        assert_eq!(a.permute(0), a);
        assert_eq!(a.permute(257), a);
        assert_eq!(a.permute(5).permute(252), a);
        assert!(a.permute(1).cosine(&a).abs() < 0.2);
        // Spot-check the shift direction: ρ^1(h)[j] = h[(j+ d -1) % d]? Our
        // convention: element 0 of permute(1) is the last element of a.
        assert_eq!(a.permute(1).data[0], a.data[256]);
    }

    #[test]
    fn bundle_preserves_majority_similarity() {
        let mut rng = Xoshiro256::seed_from_u64(4);
        let hvs: Vec<Hypervector> = (0..5).map(|_| Hypervector::random(10_000, &mut rng)).collect();
        let refs: Vec<&Hypervector> = hvs.iter().collect();
        let b = bundle(&refs);
        for hv in &hvs {
            assert!(b.cosine(hv) > 0.2, "bundle lost a member: {}", b.cosine(hv));
        }
        let outsider = Hypervector::random(10_000, &mut rng);
        assert!(b.cosine(&outsider).abs() < 0.05);
    }

    #[test]
    fn dot_and_hamming_consistent() {
        let mut rng = Xoshiro256::seed_from_u64(5);
        let a = Hypervector::random(1000, &mut rng);
        let b = Hypervector::random(1000, &mut rng);
        let dot = a.dot(&b);
        let ham = a.hamming(&b) as i64;
        assert_eq!(dot, 1000 - 2 * ham);
    }
}
