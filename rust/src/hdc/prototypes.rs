//! Class prototypes (paper §2.1.1): bundled HVs of training samples per
//! class, plus the SCE-style argmax matcher `ŷ = argmax_c sim(h, g_c)`.

use super::Hypervector;

/// Accumulates per-class element-wise sums during training, then
/// bipolarizes into prototypes (single-pass HDC training).
#[derive(Debug, Clone)]
pub struct PrototypeAccumulator {
    pub num_classes: usize,
    pub dim: usize,
    sums: Vec<Vec<i64>>,
    counts: Vec<usize>,
}

impl PrototypeAccumulator {
    pub fn new(num_classes: usize, dim: usize) -> Self {
        Self {
            num_classes,
            dim,
            sums: vec![vec![0i64; dim]; num_classes],
            counts: vec![0; num_classes],
        }
    }

    pub fn add(&mut self, class: usize, hv: &Hypervector) {
        assert!(class < self.num_classes);
        assert_eq!(hv.dim(), self.dim);
        for (s, &v) in self.sums[class].iter_mut().zip(&hv.data) {
            *s += v as i64;
        }
        self.counts[class] += 1;
    }

    pub fn finalize(self) -> ClassPrototypes {
        let prototypes = self
            .sums
            .iter()
            .map(|s| Hypervector {
                data: s.iter().map(|&v| if v < 0 { -1i8 } else { 1i8 }).collect(),
            })
            .collect();
        ClassPrototypes {
            prototypes,
            counts: self.counts,
        }
    }
}

/// The trained prototype matrix G ∈ {-1,+1}^{C×d}.
#[derive(Debug, Clone)]
pub struct ClassPrototypes {
    pub prototypes: Vec<Hypervector>,
    /// Training samples bundled into each class (diagnostics).
    pub counts: Vec<usize>,
}

impl ClassPrototypes {
    pub fn num_classes(&self) -> usize {
        self.prototypes.len()
    }

    pub fn dim(&self) -> usize {
        self.prototypes.first().map(|p| p.dim()).unwrap_or(0)
    }

    /// All class scores s = G h (integer dot products).
    pub fn scores(&self, hv: &Hypervector) -> Vec<i64> {
        self.prototypes.iter().map(|p| p.dot(hv)).collect()
    }

    /// Predicted class: argmax similarity (first max wins on ties, which
    /// matches the hardware argmax unit's sequential compare).
    pub fn classify(&self, hv: &Hypervector) -> usize {
        let scores = self.scores(hv);
        let mut best = 0usize;
        for (c, &s) in scores.iter().enumerate() {
            if s > scores[best] {
                best = c;
            }
        }
        best
    }

    /// Bytes for G at b_G bits per element (Table 2 accounting).
    pub fn bytes(&self, b_g_bits: usize) -> usize {
        self.num_classes() * self.dim() * b_g_bits / 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn prototypes_classify_their_own_clusters() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        let d = 4096;
        let centers: Vec<Hypervector> = (0..3).map(|_| Hypervector::random(d, &mut rng)).collect();
        let mut acc = PrototypeAccumulator::new(3, d);
        // Noisy copies of each center: flip 20% of coordinates.
        let noisy = |c: &Hypervector, rng: &mut Xoshiro256| -> Hypervector {
            Hypervector {
                data: c
                    .data
                    .iter()
                    .map(|&v| if rng.bernoulli(0.2) { -v } else { v })
                    .collect(),
            }
        };
        for class in 0..3 {
            for _ in 0..20 {
                acc.add(class, &noisy(&centers[class], &mut rng));
            }
        }
        let protos = acc.finalize();
        assert_eq!(protos.counts, vec![20, 20, 20]);
        let mut correct = 0;
        let trials = 60;
        for class in 0..3 {
            for _ in 0..trials / 3 {
                if protos.classify(&noisy(&centers[class], &mut rng)) == class {
                    correct += 1;
                }
            }
        }
        assert!(correct as f64 / trials as f64 > 0.95, "acc={correct}/{trials}");
    }

    #[test]
    fn tie_breaks_to_first() {
        let p = ClassPrototypes {
            prototypes: vec![
                Hypervector { data: vec![1, 1] },
                Hypervector { data: vec![1, 1] },
            ],
            counts: vec![1, 1],
        };
        assert_eq!(p.classify(&Hypervector { data: vec![1, 1] }), 0);
    }

    #[test]
    fn bytes_accounting() {
        let p = ClassPrototypes {
            prototypes: vec![Hypervector { data: vec![1; 10000] }; 2],
            counts: vec![1, 1],
        };
        assert_eq!(p.bytes(8), 2 * 10000);
        assert_eq!(p.bytes(1), 2 * 10000 / 8);
    }
}
