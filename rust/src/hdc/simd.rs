//! Runtime-dispatched SIMD backends for the packed popcount kernels.
//!
//! Every hot kernel of the bit-packed engine reduces to two word-slice
//! primitives: XOR+popcount (SCE matching, Hamming/dot) and the
//! carry-save ripple step of the bit-sliced bundle counters (training).
//! This module defines them as a [`PopcountBackend`] trait with three
//! implementations:
//!
//! * **scalar** — the portable four-lane `u64::count_ones` kernel that
//!   shipped with PR 1/2, kept as the in-process oracle every other
//!   backend must match bit-for-bit;
//! * **avx2** (x86_64) — a `std::arch` sub-byte-LUT popcount over 256-bit
//!   lanes (Mula's `vpshufb` nibble table + `vpsadbw` horizontal sums),
//!   the CPU analogue of the DSP/LUT popcount parallelism the paper's SCE
//!   exploits;
//! * **neon** (aarch64) — `vcnt`-based byte popcount over 128-bit lanes.
//!
//! # Dispatch rule
//!
//! [`active`] picks the backend **once** per process, at first use:
//! `NYSX_FORCE_SCALAR=1` forces the scalar oracle (the CI matrix runs the
//! whole test suite under both dispatch outcomes); otherwise x86_64 uses
//! AVX2 when `is_x86_feature_detected!` confirms it at runtime, aarch64
//! uses NEON (baseline on that architecture), and anything else falls
//! back to scalar. Kernels accept an explicit `&dyn PopcountBackend` via
//! their `*_with` variants so the property suite and the micro benches
//! can pin a backend regardless of the ambient dispatch; the plain entry
//! points all delegate to [`active`].
//!
//! # Equivalence contract
//!
//! Backends are required to be *bit-identical* to scalar (and therefore,
//! transitively, to the i8 reference oracle) on every input, including
//! slices whose length is not a multiple of the vector width — each
//! vector implementation handles the ragged tail with the scalar kernel.
//! `tests` below and the differential suite in [`super::packed`] enforce
//! this for every backend compiled into the current binary.

use std::sync::OnceLock;

/// Word-slice popcount kernels. Implementations must be bit-identical to
/// the scalar oracle; see the module docs for the contract.
pub trait PopcountBackend: Send + Sync {
    /// Short stable identifier ("scalar", "avx2", "neon") used by benches,
    /// test diagnostics and the serve summary.
    fn name(&self) -> &'static str;

    /// `Σ popcount(a[i] ^ b[i])` over two equal-length word slices — the
    /// SCE inner kernel (Hamming distance of two packed hypervector
    /// slices).
    fn xor_popcount(&self, a: &[u64], b: &[u64]) -> u32;

    /// One carry-save ripple step of the bit-sliced bundle counters:
    /// `plane' = plane ^ carry; carry' = plane & carry`, word-parallel
    /// over the slice. Returns `true` iff any carry bit survives (the
    /// ripple must continue into the next plane).
    fn carry_save_step(&self, plane: &mut [u64], carry: &mut [u64]) -> bool {
        scalar_carry_save_step(plane, carry)
    }
}

/// The portable scalar backend — the in-process oracle.
pub struct Scalar;

impl PopcountBackend for Scalar {
    fn name(&self) -> &'static str {
        "scalar"
    }

    fn xor_popcount(&self, a: &[u64], b: &[u64]) -> u32 {
        scalar_xor_popcount(a, b)
    }
}

/// XOR+popcount over two equal-length word slices, four independent
/// accumulator lanes. The lanes carry no cross-iteration dependency, so
/// even without an explicit SIMD backend the autovectorizer can widen
/// this into SIMD popcount sequences.
fn scalar_xor_popcount(a: &[u64], b: &[u64]) -> u32 {
    debug_assert_eq!(a.len(), b.len());
    let mut lanes = [0u32; 4];
    let chunks = a.len() / 4;
    for k in 0..chunks {
        let base = k * 4;
        lanes[0] += (a[base] ^ b[base]).count_ones();
        lanes[1] += (a[base + 1] ^ b[base + 1]).count_ones();
        lanes[2] += (a[base + 2] ^ b[base + 2]).count_ones();
        lanes[3] += (a[base + 3] ^ b[base + 3]).count_ones();
    }
    let mut tail = 0u32;
    for k in chunks * 4..a.len() {
        tail += (a[k] ^ b[k]).count_ones();
    }
    (lanes[0] + lanes[1]) + (lanes[2] + lanes[3]) + tail
}

/// Scalar carry-save ripple step (also the trait's default method, so
/// vector backends only override it where the win is real).
fn scalar_carry_save_step(plane: &mut [u64], carry: &mut [u64]) -> bool {
    debug_assert_eq!(plane.len(), carry.len());
    let mut any = 0u64;
    for (p, c) in plane.iter_mut().zip(carry.iter_mut()) {
        let old = *p;
        *p = old ^ *c;
        *c = old & *c;
        any |= *c;
    }
    any != 0
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    //! AVX2 sub-byte-LUT popcount (Mula): split each byte of `a ^ b` into
    //! nibbles, look both up in a 16-entry popcount table with `vpshufb`,
    //! and horizontally reduce the byte counts into four u64 lanes with
    //! `vpsadbw` — 256 bits of XOR+popcount per iteration with no
    //! cross-iteration dependency beyond the wide accumulator.

    use std::arch::x86_64::{
        __m256i, _mm256_add_epi64, _mm256_add_epi8, _mm256_and_si256, _mm256_loadu_si256,
        _mm256_or_si256, _mm256_sad_epu8, _mm256_set1_epi8, _mm256_setzero_si256,
        _mm256_shuffle_epi8, _mm256_srli_epi16, _mm256_storeu_si256, _mm256_testz_si256,
        _mm256_xor_si256,
    };

    use super::PopcountBackend;

    /// Per-nibble popcounts, replicated across both 128-bit halves (the
    /// `vpshufb` LUT operand).
    const NIBBLE_POP: [i8; 32] = [
        0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4, 0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3,
        3, 4,
    ];

    pub struct Avx2;

    impl PopcountBackend for Avx2 {
        fn name(&self) -> &'static str {
            "avx2"
        }

        fn xor_popcount(&self, a: &[u64], b: &[u64]) -> u32 {
            debug_assert_eq!(a.len(), b.len());
            // SAFETY: `Avx2` is only handed out by `native`/`available`
            // after `is_x86_feature_detected!("avx2")` confirmed support.
            unsafe { xor_popcount_avx2(a, b) }
        }

        fn carry_save_step(&self, plane: &mut [u64], carry: &mut [u64]) -> bool {
            debug_assert_eq!(plane.len(), carry.len());
            // SAFETY: as above — construction is gated on AVX2 detection.
            unsafe { carry_save_step_avx2(plane, carry) }
        }
    }

    /// Safety: caller must ensure the CPU supports AVX2 and
    /// `a.len() == b.len()`.
    #[target_feature(enable = "avx2")]
    unsafe fn xor_popcount_avx2(a: &[u64], b: &[u64]) -> u32 {
        let n = a.len();
        let vecs = n / 4; // four u64 words per 256-bit vector
        let pa = a.as_ptr();
        let pb = b.as_ptr();
        let lut = _mm256_loadu_si256(NIBBLE_POP.as_ptr() as *const __m256i);
        let low_mask = _mm256_set1_epi8(0x0f);
        let zero = _mm256_setzero_si256();
        // u64×4 accumulator: each `vpsadbw` contributes ≤ 64 per lane, so
        // overflow would need > 2^58 words — unreachable.
        let mut acc = zero;
        for k in 0..vecs {
            let va = _mm256_loadu_si256(pa.add(k * 4) as *const __m256i);
            let vb = _mm256_loadu_si256(pb.add(k * 4) as *const __m256i);
            let x = _mm256_xor_si256(va, vb);
            let lo = _mm256_and_si256(x, low_mask);
            let hi = _mm256_and_si256(_mm256_srli_epi16::<4>(x), low_mask);
            let cnt = _mm256_add_epi8(_mm256_shuffle_epi8(lut, lo), _mm256_shuffle_epi8(lut, hi));
            acc = _mm256_add_epi64(acc, _mm256_sad_epu8(cnt, zero));
        }
        let mut lanes = [0u64; 4];
        _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc);
        let mut total = lanes[0] + lanes[1] + lanes[2] + lanes[3];
        // Ragged tail (< 4 words): scalar popcount, bit-identical.
        for k in vecs * 4..n {
            total += (*pa.add(k) ^ *pb.add(k)).count_ones() as u64;
        }
        total as u32
    }

    /// Safety: caller must ensure the CPU supports AVX2 and
    /// `plane.len() == carry.len()`.
    #[target_feature(enable = "avx2")]
    unsafe fn carry_save_step_avx2(plane: &mut [u64], carry: &mut [u64]) -> bool {
        let n = plane.len();
        let vecs = n / 4;
        let pp = plane.as_mut_ptr();
        let pc = carry.as_mut_ptr();
        let mut any = _mm256_setzero_si256();
        for k in 0..vecs {
            let vp = _mm256_loadu_si256(pp.add(k * 4) as *const __m256i);
            let vc = _mm256_loadu_si256(pc.add(k * 4) as *const __m256i);
            let new_c = _mm256_and_si256(vp, vc);
            _mm256_storeu_si256(pp.add(k * 4) as *mut __m256i, _mm256_xor_si256(vp, vc));
            _mm256_storeu_si256(pc.add(k * 4) as *mut __m256i, new_c);
            any = _mm256_or_si256(any, new_c);
        }
        let mut more = _mm256_testz_si256(any, any) == 0;
        for k in vecs * 4..n {
            let old = *pp.add(k);
            let c = *pc.add(k);
            *pp.add(k) = old ^ c;
            *pc.add(k) = old & c;
            more |= (old & c) != 0;
        }
        more
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    //! NEON `vcnt`-based popcount: XOR two 128-bit lanes, count bits per
    //! byte with `vcnt`, and horizontally reduce with `vaddlv`. The
    //! carry-save step keeps the scalar default — two bitwise ops per
    //! word autovectorize trivially on aarch64.

    use std::arch::aarch64::{vaddlvq_u8, vcntq_u8, veorq_u64, vld1q_u64, vreinterpretq_u8_u64};

    use super::PopcountBackend;

    pub struct Neon;

    impl PopcountBackend for Neon {
        fn name(&self) -> &'static str {
            "neon"
        }

        fn xor_popcount(&self, a: &[u64], b: &[u64]) -> u32 {
            debug_assert_eq!(a.len(), b.len());
            // SAFETY: NEON is a baseline feature of aarch64, the only
            // architecture this module compiles for.
            unsafe { xor_popcount_neon(a, b) }
        }
    }

    /// Safety: caller must ensure `a.len() == b.len()` (NEON itself is
    /// baseline on aarch64).
    #[target_feature(enable = "neon")]
    unsafe fn xor_popcount_neon(a: &[u64], b: &[u64]) -> u32 {
        let n = a.len();
        let vecs = n / 2; // two u64 words per 128-bit vector
        let pa = a.as_ptr();
        let pb = b.as_ptr();
        let mut total = 0u64;
        for k in 0..vecs {
            let x = veorq_u64(vld1q_u64(pa.add(k * 2)), vld1q_u64(pb.add(k * 2)));
            total += vaddlvq_u8(vcntq_u8(vreinterpretq_u8_u64(x))) as u64;
        }
        // Ragged tail (< 2 words): scalar popcount, bit-identical.
        for k in vecs * 2..n {
            total += (*pa.add(k) ^ *pb.add(k)).count_ones() as u64;
        }
        total as u32
    }
}

/// The scalar oracle as a trait object (handy for differential tests and
/// benches that compare other backends against it).
pub fn scalar() -> &'static dyn PopcountBackend {
    &Scalar
}

/// Every backend compiled into this binary *and* usable on this host:
/// scalar first (the oracle), then the vector backend runtime detection
/// admits, if any. Differential tests iterate this list.
pub fn available() -> Vec<&'static dyn PopcountBackend> {
    let mut backends: Vec<&'static dyn PopcountBackend> = vec![&Scalar];
    let native = native();
    if native.name() != Scalar.name() {
        backends.push(native);
    }
    backends
}

/// Interpret the `NYSX_FORCE_SCALAR` value (unset, empty and "0" mean
/// "use native dispatch"; anything else forces the scalar oracle).
fn force_scalar_from(value: Option<&str>) -> bool {
    value.is_some_and(|v| !v.is_empty() && v != "0")
}

/// Pure dispatch rule, split out from the cached [`active`] so tests can
/// exercise both outcomes in one process.
fn select(force_scalar: bool) -> &'static dyn PopcountBackend {
    if force_scalar {
        return &Scalar;
    }
    native()
}

#[cfg(target_arch = "x86_64")]
fn native() -> &'static dyn PopcountBackend {
    if is_x86_feature_detected!("avx2") {
        &avx2::Avx2
    } else {
        &Scalar
    }
}

#[cfg(target_arch = "aarch64")]
fn native() -> &'static dyn PopcountBackend {
    &neon::Neon
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn native() -> &'static dyn PopcountBackend {
    &Scalar
}

static ACTIVE: OnceLock<&'static dyn PopcountBackend> = OnceLock::new();

/// The process-wide backend, selected once at first use: scalar when
/// `NYSX_FORCE_SCALAR=1`, otherwise the best the host supports (see the
/// module docs). Every plain packed-kernel entry point dispatches here.
pub fn active() -> &'static dyn PopcountBackend {
    *ACTIVE.get_or_init(|| {
        select(force_scalar_from(
            std::env::var("NYSX_FORCE_SCALAR").ok().as_deref(),
        ))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{forall, PropConfig};
    use crate::util::rng::Xoshiro256;

    fn random_words(rng: &mut Xoshiro256, len: usize) -> Vec<u64> {
        (0..len).map(|_| rng.next_u64()).collect()
    }

    #[test]
    fn scalar_is_always_available_and_first() {
        let backends = available();
        assert!(!backends.is_empty());
        assert_eq!(backends[0].name(), "scalar");
        // Names are unique — benches key comparisons on them.
        let names: std::collections::HashSet<_> = backends.iter().map(|b| b.name()).collect();
        assert_eq!(names.len(), backends.len());
    }

    #[test]
    fn dispatch_rule() {
        // Forcing scalar always yields the oracle...
        assert_eq!(select(true).name(), "scalar");
        // ...and native dispatch yields something from the available set.
        let native = select(false);
        assert!(available().iter().any(|b| b.name() == native.name()));
        // The cached process-wide choice is consistent with the rule.
        assert!(available().iter().any(|b| b.name() == active().name()));
    }

    #[test]
    fn force_scalar_env_parsing() {
        assert!(!force_scalar_from(None));
        assert!(!force_scalar_from(Some("")));
        assert!(!force_scalar_from(Some("0")));
        assert!(force_scalar_from(Some("1")));
        assert!(force_scalar_from(Some("true")));
    }

    /// Every available backend matches the scalar oracle on XOR+popcount,
    /// across lengths that straddle every vector-width boundary (the
    /// ragged sub-width tails included).
    #[test]
    fn xor_popcount_matches_scalar_on_all_backends() {
        forall("simd-xor-popcount", PropConfig::default(), |rng, size| {
            let len = rng.gen_range(4 * size.max(1) + 10);
            let a = random_words(rng, len);
            let b = random_words(rng, len);
            let want = scalar().xor_popcount(&a, &b);
            for be in available() {
                let got = be.xor_popcount(&a, &b);
                crate::prop_assert!(
                    got == want,
                    "{}: {got} != scalar {want} at len={len}",
                    be.name()
                );
            }
            Ok(())
        });
    }

    /// Every available backend performs the identical carry-save step —
    /// same planes, same carries, same "ripple continues" flag.
    #[test]
    fn carry_save_step_matches_scalar_on_all_backends() {
        forall("simd-carry-save", PropConfig::default(), |rng, size| {
            let len = rng.gen_range(4 * size.max(1) + 10);
            let plane0 = random_words(rng, len);
            let carry0 = random_words(rng, len);
            let mut want_plane = plane0.clone();
            let mut want_carry = carry0.clone();
            let want_more = scalar().carry_save_step(&mut want_plane, &mut want_carry);
            for be in available() {
                let mut plane = plane0.clone();
                let mut carry = carry0.clone();
                let more = be.carry_save_step(&mut plane, &mut carry);
                crate::prop_assert!(
                    plane == want_plane && carry == want_carry && more == want_more,
                    "{} carry-save diverged at len={len}",
                    be.name()
                );
            }
            // The step must preserve the per-word sum plane + 2·carry
            // (carry-save invariant) — checked once on the oracle output.
            for i in 0..len {
                let before = (plane0[i] & carry0[i]).count_ones() * 2
                    + (plane0[i] ^ carry0[i]).count_ones();
                let after = want_carry[i].count_ones() * 2 + want_plane[i].count_ones();
                crate::prop_assert!(
                    before == after,
                    "carry-save sum invariant broken at word {i}, len={len}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn degenerate_slices() {
        for be in available() {
            assert_eq!(be.xor_popcount(&[], &[]), 0, "{}", be.name());
            assert!(!be.carry_save_step(&mut [], &mut []), "{}", be.name());
            // All-zero carry: planes untouched, ripple stops.
            let mut plane = vec![0xDEAD_BEEFu64; 5];
            let mut carry = vec![0u64; 5];
            assert!(!be.carry_save_step(&mut plane, &mut carry), "{}", be.name());
            assert_eq!(plane, vec![0xDEAD_BEEFu64; 5], "{}", be.name());
            assert_eq!(carry, vec![0u64; 5], "{}", be.name());
        }
    }

    #[test]
    fn known_popcounts() {
        for be in available() {
            // Single fully-set word against zero: 64 bits differ.
            assert_eq!(be.xor_popcount(&[u64::MAX], &[0]), 64, "{}", be.name());
            // Identical slices: zero distance regardless of content.
            let a: Vec<u64> = (0..9u64).map(|i| i.wrapping_mul(0x9E37_79B9)).collect();
            assert_eq!(be.xor_popcount(&a, &a), 0, "{}", be.name());
            // 5 words of alternating bits vs their complement: 5 × 64.
            let x = vec![0xAAAA_AAAA_AAAA_AAAAu64; 5];
            let y = vec![0x5555_5555_5555_5555u64; 5];
            assert_eq!(be.xor_popcount(&x, &y), 320, "{}", be.name());
        }
    }
}
