//! Bit-packed bipolar hypervectors — the representation the accelerator's
//! SCE actually consumes (sign bits), 8× denser than the `Vec<i8>`
//! reference in [`super::Hypervector`].
//!
//! # Word layout
//!
//! A `d`-dimensional HV occupies `⌈d/64⌉` little-endian `u64` words:
//! element `i` lives in word `i / 64` at bit `i % 64`. Bit value `1`
//! encodes element `-1`; bit `0` encodes `+1`. This matches the repo-wide
//! sign convention `sign(0) = +1` — bipolarizing a real value sets the
//! bit iff the value is strictly negative (see
//! [`PackedHypervector::from_real`]).
//!
//! # Tail-masking convention
//!
//! When `d` is not a multiple of 64 the last word has `64 - d % 64`
//! *tail bits* above the logical dimension. The invariant maintained by
//! every constructor and operator in this module is that **tail bits are
//! always zero**, so `popcount`-based kernels (dot, Hamming, bundle
//! counters) never see phantom coordinates. Anything that writes raw
//! words ([`PackedHypervector::words_mut`]) is `pub(crate)` and must
//! re-establish the invariant; the property suite checks it after every
//! operation.
//!
//! # Operator correspondences (all bit-identical to the i8 reference)
//!
//! | i8 op                  | packed realization                       |
//! |------------------------|------------------------------------------|
//! | bind (elementwise ×)   | word-wise XOR                            |
//! | permute (cyclic shift) | cross-word bit rotate                    |
//! | hamming                | `Σ popcount(a ^ b)`                      |
//! | dot                    | `d − 2·hamming`                          |
//! | bundle (majority sign) | per-bit minus-counters, threshold `n/2`  |
//!
//! # Batch-major matching layout
//!
//! The serving path amortizes prototype traffic across queries (the
//! paper's SCE streams G once per *batch*, not once per query). The
//! operand for that is [`PackedBatch`]: W query HVs stored back-to-back,
//! query-major, each occupying exactly `words_for(d)` words with the same
//! tail-zero invariant as a single [`PackedHypervector`].
//! [`PackedPrototypes::scores_batch_into`] then walks the C×W similarity
//! matrix **blocked over words**: for each word-block of at most
//! [`BLOCK_WORDS`] words, every prototype slice is matched against every
//! query slice before the block advances, so the prototype block stays in
//! L1 while the query blocks stream through exactly once per class.
//! Scores and argmax are bit-identical to the single-query
//! [`PackedPrototypes::classify`], which the property suite enforces.
//!
//! # SIMD backend dispatch
//!
//! The popcount-shaped inner kernels — XOR+popcount for matching, the
//! carry-save ripple for the bundle counters — are routed through the
//! runtime-dispatched [`super::simd::PopcountBackend`] layer (scalar
//! oracle, AVX2, NEON; `NYSX_FORCE_SCALAR=1` pins the oracle). The plain
//! entry points (`hamming`, `classify`, `scores_batch_into`,
//! [`PackedAccumulator::add`], …) use the process-wide
//! [`super::simd::active`] backend; each has a `*_with` variant taking an
//! explicit `&dyn PopcountBackend` so differential tests and benches can
//! compare backends side by side. Every backend is property-tested
//! bit-identical to scalar here, across dims straddling word boundaries.

use super::simd::{self, PopcountBackend};
use super::Hypervector;
use crate::exec::{self, Pool};

/// Bits per storage word.
const WORD_BITS: usize = 64;

/// Number of words needed for `d` logical bits.
#[inline]
pub const fn words_for(d: usize) -> usize {
    d.div_ceil(WORD_BITS)
}

/// Mask of valid bits in the *last* word of a `d`-bit vector.
#[inline]
const fn tail_mask(d: usize) -> u64 {
    let r = d % WORD_BITS;
    if r == 0 {
        u64::MAX
    } else {
        (1u64 << r) - 1
    }
}

/// A bipolar hypervector h ∈ {-1, +1}^d packed one sign bit per element.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PackedHypervector {
    words: Box<[u64]>,
    dim: usize,
}

impl PackedHypervector {
    /// All-(+1) vector (every bit clear).
    pub fn zeros(d: usize) -> Self {
        Self {
            words: vec![0u64; words_for(d)].into_boxed_slice(),
            dim: d,
        }
    }

    #[inline]
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Raw word storage (tail bits guaranteed zero).
    #[inline]
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Mutable word storage for fused producers (e.g. the NEE
    /// project-bipolarize-pack path). Crate-internal: writers must keep
    /// tail bits zero.
    #[inline]
    pub(crate) fn words_mut(&mut self) -> &mut [u64] {
        &mut self.words
    }

    /// Storage bytes (the Table-2 `b_G = 1` accounting, word-rounded).
    pub fn bytes(&self) -> usize {
        self.words.len() * 8
    }

    /// Re-zero any tail bits after a raw word-level write.
    #[inline]
    fn mask_tail(&mut self) {
        if let Some(last) = self.words.last_mut() {
            *last &= tail_mask(self.dim);
        }
    }

    /// Element `i` as ±1.
    #[inline]
    pub fn get(&self, i: usize) -> i8 {
        debug_assert!(i < self.dim);
        if (self.words[i / WORD_BITS] >> (i % WORD_BITS)) & 1 == 1 {
            -1
        } else {
            1
        }
    }

    /// Rebuild from raw words (deserialization). Rejects payloads whose
    /// word count is wrong or whose tail bits are set — the invariant
    /// must hold before any popcount kernel runs.
    pub fn from_words(dim: usize, words: Vec<u64>) -> Result<Self, &'static str> {
        if words.len() != words_for(dim) {
            return Err("word count does not match dimension");
        }
        if let Some(&last) = words.last() {
            if last & !tail_mask(dim) != 0 {
                return Err("tail bits set beyond logical dimension");
            }
        }
        Ok(Self {
            words: words.into_boxed_slice(),
            dim,
        })
    }

    /// Pack an i8 reference HV losslessly (bit set ⇔ element negative).
    pub fn pack(hv: &Hypervector) -> Self {
        let mut out = Self::zeros(hv.dim());
        for (i, &v) in hv.data.iter().enumerate() {
            if v < 0 {
                out.words[i / WORD_BITS] |= 1 << (i % WORD_BITS);
            }
        }
        out
    }

    /// Unpack to the i8 reference representation (lossless inverse of
    /// [`Self::pack`]).
    pub fn unpack(&self) -> Hypervector {
        Hypervector {
            data: (0..self.dim).map(|i| self.get(i)).collect(),
        }
    }

    /// Bipolarize-and-pack a real vector: bit i set iff `y[i] < 0`
    /// (`sign(0) = +1`, matching [`Hypervector::from_real`]).
    pub fn from_real(y: &[f64]) -> Self {
        let mut out = Self::zeros(y.len());
        for (i, &v) in y.iter().enumerate() {
            if v < 0.0 {
                out.words[i / WORD_BITS] |= 1 << (i % WORD_BITS);
            }
        }
        out
    }

    pub fn from_real_f32(y: &[f32]) -> Self {
        let mut out = Self::zeros(y.len());
        for (i, &v) in y.iter().enumerate() {
            if v < 0.0 {
                out.words[i / WORD_BITS] |= 1 << (i % WORD_BITS);
            }
        }
        out
    }

    /// Random bipolar HV drawn word-at-a-time. NOTE: consumes the RNG
    /// stream differently from [`Hypervector::random`] (one `u64` per 64
    /// elements instead of one per element), so the two are *not*
    /// bit-equal for the same seed — pack an i8 HV when a matched pair is
    /// needed.
    pub fn random(d: usize, rng: &mut crate::util::rng::Xoshiro256) -> Self {
        let mut out = Self::zeros(d);
        for w in out.words.iter_mut() {
            *w = rng.next_u64();
        }
        out.mask_tail();
        out
    }

    /// Binding (⊗) into a caller-owned output — the allocation-free
    /// variant of [`Self::bind`] for hot loops that rebind a scratch HV
    /// per iteration (e.g. the packed GraphHD edge encoder). Tail bits
    /// stay zero (0 ^ 0 = 0).
    pub fn bind_into(&self, other: &PackedHypervector, out: &mut PackedHypervector) {
        assert_eq!(self.dim, other.dim);
        assert_eq!(self.dim, out.dim);
        for ((o, &a), &b) in out.words.iter_mut().zip(self.words.iter()).zip(other.words.iter()) {
            *o = a ^ b;
        }
    }

    /// Binding (⊗): element-wise product = word-wise XOR. Tail bits stay
    /// zero (0 ^ 0 = 0).
    pub fn bind(&self, other: &PackedHypervector) -> PackedHypervector {
        assert_eq!(self.dim, other.dim);
        PackedHypervector {
            words: self
                .words
                .iter()
                .zip(other.words.iter())
                .map(|(&a, &b)| a ^ b)
                .collect(),
            dim: self.dim,
        }
    }

    /// Permutation (ρ^i): cyclic shift by `i` positions, identical to
    /// [`Hypervector::permute`] — result element `j` is input element
    /// `(j - i) mod d`, i.e. a `d`-bit rotate towards higher bit indices,
    /// carried across word boundaries.
    pub fn permute(&self, i: usize) -> PackedHypervector {
        let d = self.dim;
        if d == 0 {
            return self.clone();
        }
        let shift = i % d;
        if shift == 0 {
            return self.clone();
        }
        let mut out = Self::zeros(d);
        shl_into(&self.words, d, shift, &mut out.words);
        let mut lo = vec![0u64; self.words.len()];
        shr_into(&self.words, d - shift, &mut lo);
        for (o, l) in out.words.iter_mut().zip(&lo) {
            *o |= l;
        }
        out.mask_tail();
        out
    }

    /// Hamming distance: popcount over the XOR. Tail bits are zero in
    /// both operands, so they contribute nothing. Dispatches to the
    /// process-wide SIMD backend ([`simd::active`]).
    pub fn hamming(&self, other: &PackedHypervector) -> usize {
        self.hamming_with(simd::active(), other)
    }

    /// [`Self::hamming`] on an explicit backend (differential testing).
    pub fn hamming_with(&self, be: &dyn PopcountBackend, other: &PackedHypervector) -> usize {
        assert_eq!(self.dim, other.dim);
        be.xor_popcount(&self.words, &other.words) as usize
    }

    /// Dot-product similarity: `d − 2·hamming` (exact for bipolar).
    pub fn dot(&self, other: &PackedHypervector) -> i64 {
        self.dot_with(simd::active(), other)
    }

    /// [`Self::dot`] on an explicit backend (differential testing).
    pub fn dot_with(&self, be: &dyn PopcountBackend, other: &PackedHypervector) -> i64 {
        self.dim as i64 - 2 * self.hamming_with(be, other) as i64
    }

    /// Cosine similarity in [-1, 1] (bipolar norm is √d).
    pub fn cosine(&self, other: &PackedHypervector) -> f64 {
        if self.dim == 0 {
            return 0.0;
        }
        self.dot(other) as f64 / self.dim as f64
    }

    /// Number of −1 elements (set bits).
    pub fn count_negatives(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }
}

/// Multiword shift towards higher bit indices by `s`, masked to `d` bits.
fn shl_into(src: &[u64], d: usize, s: usize, out: &mut [u64]) {
    let n = src.len();
    let (ws, bs) = (s / WORD_BITS, s % WORD_BITS);
    for k in 0..n {
        out[k] = if k < ws {
            0
        } else if bs == 0 {
            src[k - ws]
        } else {
            let lo = src[k - ws] << bs;
            let hi = if k >= ws + 1 {
                src[k - ws - 1] >> (WORD_BITS - bs)
            } else {
                0
            };
            lo | hi
        };
    }
    if n > 0 {
        out[n - 1] &= tail_mask(d);
    }
}

/// Multiword shift towards lower bit indices by `s`.
fn shr_into(src: &[u64], s: usize, out: &mut [u64]) {
    let n = src.len();
    let (ws, bs) = (s / WORD_BITS, s % WORD_BITS);
    for k in 0..n {
        out[k] = if k + ws >= n {
            0
        } else if bs == 0 {
            src[k + ws]
        } else {
            let lo = src[k + ws] >> bs;
            let hi = if k + ws + 1 < n {
                src[k + ws + 1] << (WORD_BITS - bs)
            } else {
                0
            };
            lo | hi
        };
    }
}

/// First-max-wins argmax over a score row — THE tie rule of the
/// hardware argmax unit's sequential compare. Every classify path
/// (sequential, class-block pool, batched, batched pool) funnels
/// through this one copy so the bit-identity contract can never drift
/// on tied scores.
fn argmax_first_max(row: &[i64]) -> usize {
    let mut best = 0usize;
    let mut best_score = i64::MIN;
    for (i, &s) in row.iter().enumerate() {
        if s > best_score {
            best = i;
            best_score = s;
        }
    }
    best
}

/// Words per cache block in the batch matcher: 512 words = 4 KiB per HV
/// slice, so a prototype slice plus a handful of query slices fit L1
/// comfortably while still amortizing the loop overhead. The inner
/// XOR+popcount over each block pair is a single
/// [`PopcountBackend::xor_popcount`] call, so per-call dispatch overhead
/// amortizes over whole blocks.
const BLOCK_WORDS: usize = 512;

/// W query hypervectors stored back-to-back, query-major — the SCE's
/// batch operand (see the module docs' batch-major matching section).
/// Every slot is `words_for(dim)` words and upholds the tail-zero
/// invariant; slots are appended with [`Self::push`] (copying an existing
/// HV) or filled in place by fused producers via the crate-internal
/// [`Self::push_zeroed`] + [`Self::query_words_mut`] pair.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PackedBatch {
    words: Vec<u64>,
    dim: usize,
    words_per_hv: usize,
    len: usize,
}

impl PackedBatch {
    /// Empty batch of `d`-dimensional queries.
    pub fn new(d: usize) -> Self {
        Self {
            words: Vec::new(),
            dim: d,
            words_per_hv: words_for(d),
            len: 0,
        }
    }

    #[inline]
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Number of queries currently in the batch (W).
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Drop all queries; keeps the allocation for reuse across batches.
    pub fn clear(&mut self) {
        self.words.clear();
        self.len = 0;
    }

    /// Append a query by copying its words.
    pub fn push(&mut self, hv: &PackedHypervector) {
        assert_eq!(hv.dim(), self.dim, "batch/query dimension mismatch");
        self.words.extend_from_slice(hv.words());
        self.len += 1;
    }

    /// Append a zeroed slot and return its index, for producers that pack
    /// directly into the batch (e.g. the fused NEE project-bipolarize-pack
    /// path). Writers must uphold the tail-zero invariant.
    pub(crate) fn push_zeroed(&mut self) -> usize {
        self.words.resize(self.words.len() + self.words_per_hv, 0);
        self.len += 1;
        self.len - 1
    }

    /// Word slice of query `q` (tail bits guaranteed zero).
    #[inline]
    pub fn query_words(&self, q: usize) -> &[u64] {
        assert!(q < self.len);
        &self.words[q * self.words_per_hv..(q + 1) * self.words_per_hv]
    }

    /// Mutable word slice of query `q`. Crate-internal: writers must keep
    /// tail bits zero.
    #[inline]
    pub(crate) fn query_words_mut(&mut self, q: usize) -> &mut [u64] {
        assert!(q < self.len);
        &mut self.words[q * self.words_per_hv..(q + 1) * self.words_per_hv]
    }

    /// The whole word arena (`len × words_per_hv` words, query-major) —
    /// for parallel producers that split it into per-query ranges and
    /// fill disjoint slots across exec lanes. Writers must keep tail
    /// bits zero.
    #[inline]
    pub(crate) fn all_words_mut(&mut self) -> &mut [u64] {
        &mut self.words
    }

    /// Words each query slot occupies (= `words_for(dim)`).
    #[inline]
    pub(crate) fn words_per_hv(&self) -> usize {
        self.words_per_hv
    }

    /// Copy query `q` out as a standalone hypervector.
    pub fn get(&self, q: usize) -> PackedHypervector {
        PackedHypervector {
            words: self.query_words(q).to_vec().into_boxed_slice(),
            dim: self.dim,
        }
    }

    /// Storage bytes of the whole batch.
    pub fn bytes(&self) -> usize {
        self.words.len() * 8
    }
}

/// Bundling (⊕) of packed HVs: majority sign per element, ties to +1 —
/// bit-identical to [`super::bundle`] on the unpacked operands.
pub fn packed_bundle(hvs: &[&PackedHypervector]) -> PackedHypervector {
    assert!(!hvs.is_empty(), "bundle of nothing");
    let d = hvs[0].dim();
    let mut acc = PackedAccumulator::new(1, d);
    for hv in hvs {
        acc.add(0, hv);
    }
    acc.finalize().prototypes.pop().expect("one bundle class")
}

/// Accumulates per-class, per-bit −1 counters during training, then
/// thresholds into packed prototypes. The element-wise sum of `n` bipolar
/// values with `m` minus-ones is `n − 2m`, so the bundled sign is −1 iff
/// `2m > n` (ties, `2m == n`, break to +1) — exactly the
/// [`super::PrototypeAccumulator`] rule without ever materializing i8.
///
/// The counters are *bit-sliced*: plane `p`, word `w` holds bit `p` of
/// the 64 per-coordinate counts covering elements `64w .. 64w+63`, and
/// adding an HV is a word-parallel carry-save ripple
/// (`sum = plane ^ carry; carry = plane & carry`) that touches
/// `⌈log₂ count⌉` words per input word instead of 64 scalar counters —
/// this is what makes packed bundling beat the i8 accumulator by far
/// more than the 8× storage factor. The ripple walks **plane-major**
/// (one [`PopcountBackend::carry_save_step`] over the whole plane slice
/// per level), so the SIMD backend widens it the same way it widens the
/// matching kernels. Planes grow on demand, so memory is
/// `⌈log₂(n+1)⌉ · ⌈d/64⌉` words per class.
#[derive(Debug, Clone)]
pub struct PackedAccumulator {
    pub num_classes: usize,
    pub dim: usize,
    /// Words per plane (= `words_for(dim)`).
    words: usize,
    /// Per class: concatenated counter planes, each `words` long.
    planes: Vec<Vec<u64>>,
    counts: Vec<usize>,
    /// Carry scratch for the plane-major ripple (reused across adds).
    carry: Vec<u64>,
}

impl PackedAccumulator {
    pub fn new(num_classes: usize, dim: usize) -> Self {
        Self {
            num_classes,
            dim,
            words: words_for(dim),
            planes: vec![Vec::new(); num_classes],
            counts: vec![0; num_classes],
            carry: Vec::new(),
        }
    }

    /// Bundle one HV into `class` on the process-wide SIMD backend.
    pub fn add(&mut self, class: usize, hv: &PackedHypervector) {
        self.add_with(simd::active(), class, hv);
    }

    /// [`Self::add`] on an explicit backend (differential testing). The
    /// counter state after an add is backend-independent — every backend's
    /// carry-save step is bit-identical to scalar.
    pub fn add_with(&mut self, be: &dyn PopcountBackend, class: usize, hv: &PackedHypervector) {
        assert!(class < self.num_classes);
        assert_eq!(hv.dim(), self.dim);
        let words = self.words;
        self.carry.clear();
        self.carry.extend_from_slice(hv.words());
        let planes = &mut self.planes[class];
        // Ripple the incoming bits up the counter planes, one word-parallel
        // carry-save step per level, until no carry survives.
        let mut more = self.carry.iter().any(|&c| c != 0);
        let mut p = 0;
        while more {
            if (p + 1) * words > planes.len() {
                // Counter overflowed every existing plane: grow by one
                // zeroed plane (appending keeps plane p at offset p·words).
                planes.resize((p + 1) * words, 0);
            }
            more = be.carry_save_step(&mut planes[p * words..(p + 1) * words], &mut self.carry);
            p += 1;
        }
        self.counts[class] += 1;
    }

    /// Fold another accumulator's counters into this one: per class,
    /// the bit-sliced counter planes are added with a word-parallel
    /// ripple-carry (full adder per plane level), and the sample counts
    /// sum. Because the counters are plain per-coordinate counts, the
    /// merged state equals what sequential adds of both accumulators'
    /// inputs — in any order — would have produced, which is what makes
    /// per-thread training accumulators mergeable deterministically
    /// (fixed part order) with bit-identical prototypes at any thread
    /// count.
    pub fn merge(&mut self, other: &PackedAccumulator) {
        assert_eq!(self.num_classes, other.num_classes, "class count mismatch");
        assert_eq!(self.dim, other.dim, "dimension mismatch");
        let words = self.words;
        let Self { planes, counts, carry, .. } = self;
        for (class, (planes, op)) in planes.iter_mut().zip(&other.planes).enumerate() {
            counts[class] += other.counts[class];
            let other_planes = if words == 0 { 0 } else { op.len() / words };
            carry.clear();
            carry.resize(words, 0);
            let mut p = 0usize;
            loop {
                let have_other = p < other_planes;
                let have_carry = carry.iter().any(|&c| c != 0);
                if !have_other && !have_carry {
                    break;
                }
                if (p + 1) * words > planes.len() {
                    planes.resize((p + 1) * words, 0);
                }
                let a_plane = &mut planes[p * words..(p + 1) * words];
                for (i, (a, cin)) in a_plane.iter_mut().zip(carry.iter_mut()).enumerate() {
                    let b = if have_other { op[p * words + i] } else { 0 };
                    let old = *a;
                    // Full adder: sum = a ⊕ b ⊕ cin, cout = ab | cin(a ⊕ b).
                    *a = old ^ b ^ *cin;
                    *cin = (old & b) | (*cin & (old ^ b));
                }
                p += 1;
            }
        }
    }

    /// Per-coordinate −1 count for `class` (reassembled from the planes;
    /// test/diagnostic helper, not on the training path).
    pub fn minus_count(&self, class: usize, i: usize) -> usize {
        assert!(class < self.num_classes && i < self.dim);
        let (wi, b) = (i / WORD_BITS, i % WORD_BITS);
        let planes = &self.planes[class];
        let nplanes = planes.len() / self.words.max(1);
        let mut m = 0usize;
        for p in 0..nplanes {
            m |= (((planes[p * self.words + wi] >> b) & 1) as usize) << p;
        }
        m
    }

    /// Threshold the counters into packed prototypes, word-parallel: the
    /// bundled sign of coordinate `i` is −1 iff `2m > n ⇔ m ≥ K` with
    /// `K = ⌊n/2⌋ + 1`, and the `m ≥ K` comparison runs bit-sliced — a
    /// running (greater, equal) mask pair walks the counter planes MSB
    /// to LSB against K's bits, deciding 64 coordinates per word step
    /// instead of reassembling each count bit by bit. This keeps the
    /// training tail packed end to end (the last per-element loop on the
    /// NysHD/NysX training path) and is pinned bit-identical to the
    /// per-bit reference by [`Self::minus_count`]-based tests and the i8
    /// differential suite.
    pub fn finalize(self) -> PackedPrototypes {
        let (dim, words) = (self.dim, self.words);
        let prototypes = self
            .planes
            .iter()
            .zip(&self.counts)
            .map(|(planes, &n)| Self::finalize_class(planes, n, dim, words))
            .collect();
        PackedPrototypes {
            prototypes,
            counts: self.counts,
        }
    }

    /// [`Self::finalize`] across an exec pool: one part per class (the
    /// per-class threshold walks are fully independent), results
    /// collected in class order — bit-identical to the sequential
    /// finalize at any thread count. Like every `*_with_pool` entry
    /// point, an explicit pool always partitions (very large C is
    /// exactly when callers reach for this).
    pub fn finalize_with_pool(self, pool: &Pool) -> PackedPrototypes {
        let (dim, words) = (self.dim, self.words);
        let prototypes = exec::map_parts(pool, self.num_classes, |class| {
            Self::finalize_class(&self.planes[class], self.counts[class], dim, words)
        });
        PackedPrototypes {
            prototypes,
            counts: self.counts,
        }
    }

    /// Threshold one class's counter planes into its packed prototype —
    /// the (gt, eq) MSB→LSB bit-sliced walk shared by [`Self::finalize`]
    /// and [`Self::finalize_with_pool`].
    fn finalize_class(planes: &[u64], n: usize, dim: usize, words: usize) -> PackedHypervector {
        let mut p = PackedHypervector::zeros(dim);
        if words == 0 || n == 0 {
            return p; // no samples: every sum is 0 → all +1
        }
        let nplanes = planes.len() / words;
        let k = n / 2 + 1; // bit set ⇔ m ≥ k ⇔ 2m > n
        let kbits = (usize::BITS - k.leading_zeros()) as usize;
        let top = nplanes.max(kbits);
        for (wi, out) in p.words.iter_mut().enumerate() {
            let mut gt = 0u64;
            let mut eq = u64::MAX;
            for pl in (0..top).rev() {
                let m = if pl < nplanes { planes[pl * words + wi] } else { 0 };
                let kb = if pl < usize::BITS as usize && (k >> pl) & 1 == 1 {
                    u64::MAX
                } else {
                    0
                };
                gt |= eq & m & !kb;
                eq &= !(m ^ kb);
            }
            *out = gt | eq; // m > K or m == K
        }
        // Tail coordinates have m = 0 < K, so their bits are
        // already clear; mask anyway to keep the invariant
        // obvious.
        p.mask_tail();
        p
    }
}

/// The trained prototype matrix G ∈ {-1,+1}^{C×d} at one bit per element —
/// the SCE's deployed operand. `scores`/`classify` are bit-identical to
/// [`super::ClassPrototypes`] on the unpacked prototypes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PackedPrototypes {
    pub prototypes: Vec<PackedHypervector>,
    /// Training samples bundled into each class (diagnostics).
    pub counts: Vec<usize>,
}

impl PackedPrototypes {
    pub fn num_classes(&self) -> usize {
        self.prototypes.len()
    }

    pub fn dim(&self) -> usize {
        self.prototypes.first().map(|p| p.dim()).unwrap_or(0)
    }

    /// All class scores s = G h (integer dot products via popcount).
    pub fn scores(&self, hv: &PackedHypervector) -> Vec<i64> {
        self.scores_with(simd::active(), hv)
    }

    /// [`Self::scores`] on an explicit backend (differential testing).
    pub fn scores_with(&self, be: &dyn PopcountBackend, hv: &PackedHypervector) -> Vec<i64> {
        self.prototypes.iter().map(|p| p.dot_with(be, hv)).collect()
    }

    /// [`Self::scores`] across an exec pool: the classes are split into
    /// contiguous blocks ([`exec::class_blocks`]) and each lane fills
    /// its own disjoint run of the scores vector — per-class dots are
    /// computed by exactly one lane, so the result is bit-identical at
    /// any thread count.
    pub fn scores_pool(
        &self,
        pool: &Pool,
        be: &dyn PopcountBackend,
        hv: &PackedHypervector,
    ) -> Vec<i64> {
        let mut out = vec![0i64; self.num_classes()];
        self.scores_into_pool(pool, be, hv, &mut out);
        out
    }

    /// [`Self::scores_pool`] into a caller-owned buffer (`out.len()`
    /// must equal the class count).
    pub fn scores_into_pool(
        &self,
        pool: &Pool,
        be: &dyn PopcountBackend,
        hv: &PackedHypervector,
        out: &mut [i64],
    ) {
        let c = self.num_classes();
        assert_eq!(out.len(), c, "scores buffer must have one slot per class");
        let blocks = exec::class_blocks(c, pool.threads());
        exec::for_each_range_mut(pool, out, &blocks, |block, part| {
            let classes = blocks[block].clone();
            for (slot, ci) in part.iter_mut().zip(classes) {
                *slot = self.prototypes[ci].dot_with(be, hv);
            }
        });
    }

    /// [`Self::classify`] across an exec pool: class-block-parallel
    /// scores, then the same sequential first-max-wins argmax — ties
    /// resolve identically to the single-threaded path.
    pub fn classify_pool(
        &self,
        pool: &Pool,
        be: &dyn PopcountBackend,
        hv: &PackedHypervector,
    ) -> usize {
        if self.prototypes.is_empty() {
            return 0;
        }
        argmax_first_max(&self.scores_pool(pool, be, hv))
    }

    /// Predicted class: argmax similarity, first max wins on ties (the
    /// hardware argmax unit's sequential compare).
    pub fn classify(&self, hv: &PackedHypervector) -> usize {
        self.classify_with(simd::active(), hv)
    }

    /// [`Self::classify`] on an explicit backend (differential testing).
    pub fn classify_with(&self, be: &dyn PopcountBackend, hv: &PackedHypervector) -> usize {
        let mut best = 0usize;
        let mut best_score = i64::MIN;
        for (c, p) in self.prototypes.iter().enumerate() {
            let s = p.dot_with(be, hv);
            if s > best_score {
                best = c;
                best_score = s;
            }
        }
        best
    }

    /// Blocked batch scores: the full C×W similarity matrix `S = G Q^T`
    /// written row-major by query (`out[q * C + c]` = dot of query `q`
    /// with prototype `c`), bit-identical to calling [`Self::scores`] per
    /// query. `out` must hold exactly `num_classes × batch.len()` values.
    ///
    /// The walk is cache-blocked over words: within each block of at most
    /// [`BLOCK_WORDS`] words, every prototype slice is matched against
    /// every query slice ([`PopcountBackend::xor_popcount`] inner
    /// kernel), so G's block is read from L1 W times instead of streaming
    /// all of G once per query.
    pub fn scores_batch_into(&self, batch: &PackedBatch, out: &mut [i64]) {
        // Above the parallelism threshold the global exec pool splits
        // the query axis; below it (or with one lane) this is the plain
        // blocked walk. Either way the scores are bit-identical.
        let work = self.num_classes() * batch.len() * words_for(self.dim());
        let pool = exec::global();
        if exec::worth_parallelizing(&pool, work, exec::PAR_MIN_WORDS) {
            return self.scores_batch_into_pool(&pool, simd::active(), batch, out);
        }
        self.scores_batch_into_with(simd::active(), batch, out)
    }

    /// [`Self::scores_batch_into`] on an explicit backend (differential
    /// testing).
    pub fn scores_batch_into_with(
        &self,
        be: &dyn PopcountBackend,
        batch: &PackedBatch,
        out: &mut [i64],
    ) {
        let c = self.num_classes();
        let w = batch.len();
        assert_eq!(out.len(), c * w, "scores buffer must be C x W");
        if c == 0 || w == 0 {
            return;
        }
        assert_eq!(batch.dim(), self.dim(), "batch/prototype dimension mismatch");
        self.scores_rows_into_with(be, batch, 0..w, out);
    }

    /// The blocked C×W walk restricted to queries `q_range`, writing the
    /// `(q_range.len()) × C` score rows into `out` — the per-lane core
    /// shared by the sequential and pool paths (callers validated
    /// shapes).
    fn scores_rows_into_with(
        &self,
        be: &dyn PopcountBackend,
        batch: &PackedBatch,
        q_range: std::ops::Range<usize>,
        out: &mut [i64],
    ) {
        let c = self.num_classes();
        let d = self.dim();
        debug_assert_eq!(out.len(), c * q_range.len());
        // Accumulate Hamming distances blockwise, then convert in place.
        out.iter_mut().for_each(|v| *v = 0);
        let nw = words_for(d);
        let mut w0 = 0;
        while w0 < nw {
            let w1 = (w0 + BLOCK_WORDS).min(nw);
            for (ci, proto) in self.prototypes.iter().enumerate() {
                let pw = &proto.words()[w0..w1];
                for qi in q_range.clone() {
                    let qw = &batch.query_words(qi)[w0..w1];
                    out[(qi - q_range.start) * c + ci] += be.xor_popcount(pw, qw) as i64;
                }
            }
            w0 = w1;
        }
        for v in out.iter_mut() {
            *v = d as i64 - 2 * *v;
        }
    }

    /// [`Self::scores_batch_into`] across an exec pool: the query axis
    /// is split into contiguous blocks ([`exec::even_ranges`]) so each
    /// lane owns a disjoint run of score rows and walks its queries with
    /// the identical blocked kernel — every (class, query) cell is
    /// computed by exactly one lane in the same word-block order, so the
    /// C×W matrix is bit-identical at any thread count.
    pub fn scores_batch_into_pool(
        &self,
        pool: &Pool,
        be: &dyn PopcountBackend,
        batch: &PackedBatch,
        out: &mut [i64],
    ) {
        let c = self.num_classes();
        let w = batch.len();
        assert_eq!(out.len(), c * w, "scores buffer must be C x W");
        if c == 0 || w == 0 {
            return;
        }
        assert_eq!(batch.dim(), self.dim(), "batch/prototype dimension mismatch");
        let q_ranges = exec::even_ranges(w, pool.threads());
        let row_ranges: Vec<std::ops::Range<usize>> =
            q_ranges.iter().map(|r| r.start * c..r.end * c).collect();
        exec::for_each_range_mut(pool, out, &row_ranges, |block, part| {
            self.scores_rows_into_with(be, batch, q_ranges[block].clone(), part);
        });
    }

    /// Allocating convenience wrapper around [`Self::scores_batch_into`].
    pub fn scores_batch(&self, batch: &PackedBatch) -> Vec<i64> {
        let mut out = vec![0i64; self.num_classes() * batch.len()];
        self.scores_batch_into(batch, &mut out);
        out
    }

    /// Batch classification into caller-owned scratch: `preds[q]` is the
    /// argmax class for query `q` under the same first-max-wins tie rule
    /// as [`Self::classify`] (bit-identical per query). `scores` is the
    /// reusable C×W staging buffer; both vectors are cleared and refilled.
    pub fn classify_batch_into(
        &self,
        batch: &PackedBatch,
        scores: &mut Vec<i64>,
        preds: &mut Vec<usize>,
    ) {
        let work = self.num_classes() * batch.len() * words_for(self.dim());
        let pool = exec::global();
        if exec::worth_parallelizing(&pool, work, exec::PAR_MIN_WORDS) {
            return self.classify_batch_into_pool(&pool, simd::active(), batch, scores, preds);
        }
        self.classify_batch_into_with(simd::active(), batch, scores, preds)
    }

    /// [`Self::classify_batch_into`] across an exec pool: pool-parallel
    /// blocked scoring, then the same sequential first-max-wins argmax
    /// per query — bit-identical predictions at any thread count.
    pub fn classify_batch_into_pool(
        &self,
        pool: &Pool,
        be: &dyn PopcountBackend,
        batch: &PackedBatch,
        scores: &mut Vec<i64>,
        preds: &mut Vec<usize>,
    ) {
        let c = self.num_classes();
        let w = batch.len();
        scores.clear();
        scores.resize(c * w, 0);
        preds.clear();
        if w == 0 {
            return;
        }
        if c == 0 {
            preds.resize(w, 0);
            return;
        }
        self.scores_batch_into_pool(pool, be, batch, scores);
        for qi in 0..w {
            preds.push(argmax_first_max(&scores[qi * c..(qi + 1) * c]));
        }
    }

    /// [`Self::classify_batch_into`] on an explicit backend (differential
    /// testing).
    pub fn classify_batch_into_with(
        &self,
        be: &dyn PopcountBackend,
        batch: &PackedBatch,
        scores: &mut Vec<i64>,
        preds: &mut Vec<usize>,
    ) {
        let c = self.num_classes();
        let w = batch.len();
        scores.clear();
        scores.resize(c * w, 0);
        preds.clear();
        if w == 0 {
            return;
        }
        if c == 0 {
            // Degenerate prototype-less model: classify() returns 0.
            preds.resize(w, 0);
            return;
        }
        self.scores_batch_into_with(be, batch, scores);
        for qi in 0..w {
            preds.push(argmax_first_max(&scores[qi * c..(qi + 1) * c]));
        }
    }

    /// Allocating convenience wrapper around [`Self::classify_batch_into`].
    pub fn classify_batch(&self, batch: &PackedBatch) -> Vec<usize> {
        let mut scores = Vec::new();
        let mut preds = Vec::new();
        self.classify_batch_into(batch, &mut scores, &mut preds);
        preds
    }

    /// Deployed G bytes (1 bit/element, word-rounded per prototype).
    pub fn bytes(&self) -> usize {
        self.prototypes.iter().map(|p| p.bytes()).sum()
    }

    /// Lossless conversion from the i8 reference prototypes.
    pub fn from_reference(protos: &super::ClassPrototypes) -> Self {
        Self {
            prototypes: protos.prototypes.iter().map(PackedHypervector::pack).collect(),
            counts: protos.counts.clone(),
        }
    }

    /// Lossless conversion back to the i8 reference prototypes.
    pub fn to_reference(&self) -> super::ClassPrototypes {
        super::ClassPrototypes {
            prototypes: self.prototypes.iter().map(|p| p.unpack()).collect(),
            counts: self.counts.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::{bundle, ClassPrototypes, Hypervector, PrototypeAccumulator};
    use super::*;
    use crate::testing::{forall, PropConfig};
    use crate::util::rng::Xoshiro256;

    /// The tail-masking invariant: no bit above the logical dimension.
    fn tail_clean(p: &PackedHypervector) -> bool {
        p.words.last().map(|&w| w & !tail_mask(p.dim)).unwrap_or(0) == 0
    }

    /// A dimension that deliberately hovers around word boundaries as the
    /// case size ramps: mixes exact multiples of 64, off-by-one dims and
    /// arbitrary ones.
    fn random_dim(rng: &mut Xoshiro256, size: usize) -> usize {
        match rng.gen_range(4) {
            0 => 64 * (1 + rng.gen_range(size.max(1))),
            1 => 64 * (1 + rng.gen_range(size.max(1))) + 1,
            2 => 64 * (1 + rng.gen_range(size.max(1))) - 1,
            _ => 1 + rng.gen_range(64 * size.max(1)),
        }
    }

    fn matched_pair(rng: &mut Xoshiro256, d: usize) -> (Hypervector, PackedHypervector) {
        let h = Hypervector::random(d, rng);
        let p = h.pack();
        (h, p)
    }

    #[test]
    fn pack_unpack_roundtrip_and_tail_invariant() {
        forall("pack-roundtrip", PropConfig::default(), |rng, size| {
            let d = random_dim(rng, size);
            let (h, p) = matched_pair(rng, d);
            crate::prop_assert!(p.unpack() == h, "roundtrip lost data at d={d}");
            crate::prop_assert!(tail_clean(&p), "tail bits set after pack at d={d}");
            crate::prop_assert!(p.dim() == d && p.words().len() == words_for(d), "shape d={d}");
            // Element accessor agrees with the i8 data.
            for i in 0..d.min(130) {
                crate::prop_assert!(p.get(i) == h.data[i], "get({i}) mismatch at d={d}");
            }
            Ok(())
        });
    }

    #[test]
    fn bind_matches_reference_and_is_self_inverse() {
        forall("bind-differential", PropConfig::default(), |rng, size| {
            let d = random_dim(rng, size);
            let (a, pa) = matched_pair(rng, d);
            let (b, pb) = matched_pair(rng, d);
            let bound = pa.bind(&pb);
            crate::prop_assert!(bound == a.bind(&b).pack(), "bind differs at d={d}");
            crate::prop_assert!(tail_clean(&bound), "bind leaked tail bits at d={d}");
            // Self-inverse law: (a⊗b)⊗b == a.
            crate::prop_assert!(bound.bind(&pb) == pa, "bind not self-inverse at d={d}");
            Ok(())
        });
    }

    #[test]
    fn permute_matches_reference_and_forms_cyclic_group() {
        forall("permute-differential", PropConfig::default(), |rng, size| {
            let d = random_dim(rng, size);
            let (h, p) = matched_pair(rng, d);
            let shift = rng.gen_range(3 * d + 2);
            let rotated = p.permute(shift);
            crate::prop_assert!(
                rotated == h.permute(shift).pack(),
                "permute({shift}) differs at d={d}"
            );
            crate::prop_assert!(tail_clean(&rotated), "permute leaked tail bits at d={d}");
            // Cyclic-group laws: identity, full cycle, inverse composition.
            crate::prop_assert!(p.permute(0) == p, "permute(0) != id at d={d}");
            crate::prop_assert!(p.permute(d) == p, "permute(d) != id at d={d}");
            let s = shift % d;
            crate::prop_assert!(
                rotated.permute(d - s) == p,
                "permute({s}) then permute({}) != id at d={d}",
                d - s
            );
            Ok(())
        });
    }

    #[test]
    fn similarities_match_reference() {
        forall("similarity-differential", PropConfig::default(), |rng, size| {
            let d = random_dim(rng, size);
            let (a, pa) = matched_pair(rng, d);
            let (b, pb) = matched_pair(rng, d);
            let (ham, pham) = (a.hamming(&b), pa.hamming(&pb));
            crate::prop_assert!(ham == pham, "hamming {ham} vs {pham} at d={d}");
            let (dot, pdot) = (a.dot(&b), pa.dot(&pb));
            crate::prop_assert!(dot == pdot, "dot {dot} vs {pdot} at d={d}");
            // Bipolar identity ties the two kernels together.
            crate::prop_assert!(
                pdot == d as i64 - 2 * pham as i64,
                "dot != d-2*hamming at d={d}"
            );
            // Cosine is dot/d in both representations — exact f64 equality.
            crate::prop_assert!(
                a.cosine(&b) == pa.cosine(&pb),
                "cosine differs at d={d}"
            );
            crate::prop_assert!(
                pa.count_negatives() == a.data.iter().filter(|&&v| v < 0).count(),
                "count_negatives differs at d={d}"
            );
            Ok(())
        });
    }

    #[test]
    fn bundle_matches_reference() {
        forall("bundle-differential", PropConfig::default(), |rng, size| {
            let d = random_dim(rng, size);
            // Odd and even member counts exercise the tie→+1 rule.
            let k = 1 + rng.gen_range(size.max(1) + 4);
            let pairs: Vec<(Hypervector, PackedHypervector)> =
                (0..k).map(|_| matched_pair(rng, d)).collect();
            let i8_refs: Vec<&Hypervector> = pairs.iter().map(|(h, _)| h).collect();
            let packed_refs: Vec<&PackedHypervector> = pairs.iter().map(|(_, p)| p).collect();
            let want = bundle(&i8_refs).pack();
            let got = packed_bundle(&packed_refs);
            crate::prop_assert!(got == want, "bundle of {k} differs at d={d}");
            crate::prop_assert!(tail_clean(&got), "bundle leaked tail bits at d={d}");
            Ok(())
        });
    }

    #[test]
    fn from_real_matches_reference_sign_convention() {
        forall("from-real-differential", PropConfig::default(), |rng, size| {
            let d = random_dim(rng, size);
            // Sprinkle exact zeros: sign(0) must go to +1 (bit clear).
            let y: Vec<f64> = (0..d)
                .map(|_| if rng.bernoulli(0.15) { 0.0 } else { rng.normal() })
                .collect();
            let packed = PackedHypervector::from_real(&y);
            crate::prop_assert!(
                packed == Hypervector::from_real(&y).pack(),
                "from_real differs at d={d}"
            );
            let y32: Vec<f32> = y.iter().map(|&v| v as f32).collect();
            crate::prop_assert!(
                PackedHypervector::from_real_f32(&y32) == Hypervector::from_real_f32(&y32).pack(),
                "from_real_f32 differs at d={d}"
            );
            crate::prop_assert!(tail_clean(&packed), "from_real leaked tail bits at d={d}");
            Ok(())
        });
    }

    #[test]
    fn accumulator_matches_i8_prototype_training() {
        forall("accumulator-differential", PropConfig::default(), |rng, size| {
            let d = random_dim(rng, size.min(8));
            let classes = 1 + rng.gen_range(4);
            let n = 1 + rng.gen_range(size.max(1) + 6);
            let mut i8_acc = PrototypeAccumulator::new(classes, d);
            let mut packed_acc = PackedAccumulator::new(classes, d);
            for _ in 0..n {
                let class = rng.gen_range(classes);
                let (h, p) = matched_pair(rng, d);
                i8_acc.add(class, &h);
                packed_acc.add(class, &p);
            }
            let want: ClassPrototypes = i8_acc.finalize();
            let got: PackedPrototypes = packed_acc.finalize();
            crate::prop_assert!(
                got == PackedPrototypes::from_reference(&want),
                "packed prototypes differ at d={d}, classes={classes}, n={n}"
            );
            crate::prop_assert!(
                got.to_reference().prototypes == want.prototypes,
                "unpacked prototypes differ at d={d}"
            );
            crate::prop_assert!(got.counts == want.counts, "counts differ");
            // Classification agrees on fresh queries (same scores, same
            // first-max tie-break).
            let (q, pq) = matched_pair(rng, d);
            crate::prop_assert!(
                got.scores(&pq) == want.scores(&q),
                "scores differ at d={d}"
            );
            crate::prop_assert!(
                got.classify(&pq) == want.classify(&q),
                "classify differs at d={d}"
            );
            Ok(())
        });
    }

    #[test]
    fn bind_into_matches_bind() {
        forall("bind-into", PropConfig::default(), |rng, size| {
            let d = random_dim(rng, size);
            let (_, pa) = matched_pair(rng, d);
            let (_, pb) = matched_pair(rng, d);
            let mut out = PackedHypervector::zeros(d);
            pa.bind_into(&pb, &mut out);
            crate::prop_assert!(out == pa.bind(&pb), "bind_into differs at d={d}");
            crate::prop_assert!(tail_clean(&out), "bind_into leaked tail bits at d={d}");
            Ok(())
        });
    }

    /// THE batch-major equivalence property: blocked C×W matching is
    /// bit-identical to W independent single-query calls, which are
    /// themselves bit-identical to the i8 oracle.
    #[test]
    fn batch_matching_matches_single_query_and_oracle() {
        forall("batch-matching-differential", PropConfig::default(), |rng, size| {
            let d = random_dim(rng, size.min(12));
            let classes = 1 + rng.gen_range(5);
            let n = classes + rng.gen_range(size.max(1) + 4);
            let mut i8_acc = PrototypeAccumulator::new(classes, d);
            let mut packed_acc = PackedAccumulator::new(classes, d);
            for _ in 0..n {
                let class = rng.gen_range(classes);
                let (h, p) = matched_pair(rng, d);
                i8_acc.add(class, &h);
                packed_acc.add(class, &p);
            }
            let oracle: ClassPrototypes = i8_acc.finalize();
            let protos: PackedPrototypes = packed_acc.finalize();

            // Odd batch widths around the blocking/unroll boundaries.
            let w = 1 + rng.gen_range(2 * size.max(1) + 9);
            let queries: Vec<(Hypervector, PackedHypervector)> =
                (0..w).map(|_| matched_pair(rng, d)).collect();
            let mut batch = PackedBatch::new(d);
            for (_, p) in &queries {
                batch.push(p);
            }
            crate::prop_assert!(batch.len() == w && batch.dim() == d, "batch shape");

            let scores = protos.scores_batch(&batch);
            let preds = protos.classify_batch(&batch);
            crate::prop_assert!(preds.len() == w, "preds length");
            for (qi, (h, p)) in queries.iter().enumerate() {
                let row = &scores[qi * classes..(qi + 1) * classes];
                crate::prop_assert!(
                    row == protos.scores(p).as_slice(),
                    "batch scores != single-query scores at q={qi}, d={d}"
                );
                crate::prop_assert!(
                    row == oracle.scores(h).as_slice(),
                    "batch scores != i8 oracle at q={qi}, d={d}"
                );
                crate::prop_assert!(
                    preds[qi] == protos.classify(p),
                    "batch classify != single classify at q={qi}, d={d}"
                );
                crate::prop_assert!(
                    preds[qi] == oracle.classify(h),
                    "batch classify != i8 oracle at q={qi}, d={d}"
                );
                // Batch slots roundtrip losslessly.
                crate::prop_assert!(batch.get(qi) == *p, "batch slot {qi} corrupted");
            }
            Ok(())
        });
    }

    #[test]
    fn batch_fused_slot_writes_match_push() {
        // push_zeroed + query_words_mut (the fused-producer path) must
        // produce the same batch as push() of the same HVs.
        let mut rng = Xoshiro256::seed_from_u64(17);
        for &d in &[1usize, 64, 65, 1000] {
            let hvs: Vec<PackedHypervector> = (0..5)
                .map(|_| PackedHypervector::random(d, &mut rng))
                .collect();
            let mut pushed = PackedBatch::new(d);
            let mut fused = PackedBatch::new(d);
            for hv in &hvs {
                pushed.push(hv);
                let slot = fused.push_zeroed();
                fused.query_words_mut(slot).copy_from_slice(hv.words());
            }
            assert_eq!(pushed, fused, "fused batch differs at d={d}");
            assert_eq!(pushed.bytes(), 5 * words_for(d) * 8);
        }
    }

    #[test]
    fn batch_reuse_and_degenerate_shapes() {
        let mut rng = Xoshiro256::seed_from_u64(23);
        let d = 130;
        let mut acc = PackedAccumulator::new(2, d);
        for _ in 0..6 {
            let class = rng.gen_range(2);
            let hv = PackedHypervector::random(d, &mut rng);
            acc.add(class, &hv);
        }
        let protos = acc.finalize();

        // Empty batch: no scores, no predictions.
        let mut batch = PackedBatch::new(d);
        assert!(batch.is_empty());
        assert!(protos.scores_batch(&batch).is_empty());
        assert!(protos.classify_batch(&batch).is_empty());

        // clear() keeps the batch usable and results stay correct.
        for round in 0..3 {
            batch.clear();
            let w = 1 + round;
            let queries: Vec<PackedHypervector> = (0..w)
                .map(|_| PackedHypervector::random(d, &mut rng))
                .collect();
            for q in &queries {
                batch.push(q);
            }
            let preds = protos.classify_batch(&batch);
            for (qi, q) in queries.iter().enumerate() {
                assert_eq!(preds[qi], protos.classify(q), "round {round} q {qi}");
            }
        }

        // Zero classes: every query maps to class 0, like classify().
        let none = PackedAccumulator::new(0, d).finalize();
        let q = PackedHypervector::random(d, &mut rng);
        batch.clear();
        batch.push(&q);
        assert_eq!(none.classify(&q), 0);
        assert_eq!(none.classify_batch(&batch), vec![0]);
        assert!(none.scores_batch(&batch).is_empty());
    }

    #[test]
    fn from_words_validates_payload() {
        // Wrong word count.
        assert!(PackedHypervector::from_words(65, vec![0u64]).is_err());
        // Tail bit set beyond the logical dimension.
        assert!(PackedHypervector::from_words(65, vec![0, 0b10]).is_err());
        // Valid payloads roundtrip.
        let p = PackedHypervector::from_words(65, vec![u64::MAX, 1]).unwrap();
        assert_eq!(p.dim(), 65);
        assert_eq!(p.get(64), -1);
        assert_eq!(p.count_negatives(), 65);
        // dim 0 and exact-multiple dims.
        assert!(PackedHypervector::from_words(0, vec![]).is_ok());
        assert!(PackedHypervector::from_words(128, vec![u64::MAX; 2]).is_ok());
    }

    #[test]
    fn fixed_boundary_dims_differential_spot_checks() {
        let mut rng = Xoshiro256::seed_from_u64(99);
        for &d in &[1usize, 2, 63, 64, 65, 127, 128, 129, 191, 192, 1000, 10_000] {
            let (a, pa) = matched_pair(&mut rng, d);
            let (b, pb) = matched_pair(&mut rng, d);
            assert_eq!(pa.bind(&pb), a.bind(&b).pack(), "bind d={d}");
            assert_eq!(pa.hamming(&pb), a.hamming(&b), "hamming d={d}");
            assert_eq!(pa.dot(&pb), a.dot(&b), "dot d={d}");
            for shift in [0usize, 1, 63, 64, 65, d / 2, d - 1, d, d + 1, 3 * d] {
                assert_eq!(pa.permute(shift), a.permute(shift).pack(), "permute({shift}) d={d}");
            }
        }
    }

    #[test]
    fn random_packed_is_balanced_and_masked() {
        let mut rng = Xoshiro256::seed_from_u64(5);
        let p = PackedHypervector::random(10_001, &mut rng);
        assert!(tail_clean(&p));
        let neg = p.count_negatives() as f64 / 10_001.0;
        assert!((neg - 0.5).abs() < 0.05, "negative fraction {neg}");
        // Packed random HVs stay quasi-orthogonal, like the i8 ones.
        let q = PackedHypervector::random(10_001, &mut rng);
        assert!(p.cosine(&q).abs() < 0.05);
        assert!((p.cosine(&p) - 1.0).abs() < 1e-12);
    }

    /// THE backend-differential property: every SIMD backend compiled
    /// into this binary is bit-identical to the scalar oracle on the
    /// three threaded hot paths — similarity kernels, blocked C×W batch
    /// scoring, and the carry-save bundle counters through finalize —
    /// across dims that straddle word boundaries.
    #[test]
    fn backends_match_scalar_on_all_kernels() {
        let scalar = simd::scalar();
        forall("backend-differential", PropConfig::default(), |rng, size| {
            let d = random_dim(rng, size.min(10));
            let backends = simd::available();

            // Pairwise similarity kernels.
            let a = PackedHypervector::random(d, rng);
            let b = PackedHypervector::random(d, rng);
            let want_ham = a.hamming_with(scalar, &b);
            for be in &backends {
                crate::prop_assert!(
                    a.hamming_with(*be, &b) == want_ham,
                    "{} hamming differs at d={d}",
                    be.name()
                );
                crate::prop_assert!(
                    a.dot_with(*be, &b) == a.dot_with(scalar, &b),
                    "{} dot differs at d={d}",
                    be.name()
                );
            }

            // Bundle counters: identical prototypes whichever backend ran
            // the carry-save ripple during training.
            let classes = 1 + rng.gen_range(3);
            let n = 1 + rng.gen_range(size.max(1) + 5);
            let members: Vec<(usize, PackedHypervector)> = (0..n)
                .map(|_| (rng.gen_range(classes), PackedHypervector::random(d, rng)))
                .collect();
            let mut scalar_acc = PackedAccumulator::new(classes, d);
            for (class, hv) in &members {
                scalar_acc.add_with(scalar, *class, hv);
            }
            let want_protos = scalar_acc.finalize();
            for be in &backends {
                let mut acc = PackedAccumulator::new(classes, d);
                for (class, hv) in &members {
                    acc.add_with(*be, *class, hv);
                }
                crate::prop_assert!(
                    acc.finalize() == want_protos,
                    "{} accumulator finalize differs at d={d}, n={n}",
                    be.name()
                );
            }

            // Single-query classify and blocked batch scoring.
            let w = 1 + rng.gen_range(size.max(1) + 4);
            let mut batch = PackedBatch::new(d);
            for _ in 0..w {
                batch.push(&PackedHypervector::random(d, rng));
            }
            let mut want_scores = vec![0i64; classes * w];
            want_protos.scores_batch_into_with(scalar, &batch, &mut want_scores);
            for be in &backends {
                let mut got = vec![0i64; classes * w];
                want_protos.scores_batch_into_with(*be, &batch, &mut got);
                crate::prop_assert!(
                    got == want_scores,
                    "{} batch scores differ at d={d}, w={w}",
                    be.name()
                );
                for qi in 0..w {
                    let q = batch.get(qi);
                    crate::prop_assert!(
                        want_protos.classify_with(*be, &q)
                            == want_protos.classify_with(scalar, &q),
                        "{} classify differs at d={d}, q={qi}",
                        be.name()
                    );
                }
            }
            Ok(())
        });
    }

    /// The exec contract on the SCE: class-block single-query scoring
    /// and query-block batch scoring are bit-identical to the sequential
    /// kernels (and transitively to the i8 oracle) at thread counts
    /// {1, 2, 7} across word-boundary dims.
    #[test]
    fn pool_matchers_bit_identical_across_thread_counts() {
        let pools: Vec<crate::exec::Pool> =
            [1usize, 2, 7].iter().map(|&t| crate::exec::Pool::new(t)).collect();
        let be = simd::active();
        let mut rng = Xoshiro256::seed_from_u64(77);
        for &d in &[63usize, 64, 65, 1000] {
            for &classes in &[1usize, 2, 5, 9] {
                let mut acc = PackedAccumulator::new(classes, d);
                for i in 0..3 * classes + 4 {
                    acc.add(i % classes, &PackedHypervector::random(d, &mut rng));
                }
                let protos = acc.finalize();
                let w = 7;
                let mut batch = PackedBatch::new(d);
                for _ in 0..w {
                    batch.push(&PackedHypervector::random(d, &mut rng));
                }
                let mut want = vec![0i64; classes * w];
                protos.scores_batch_into_with(be, &batch, &mut want);
                let mut want_scores = Vec::new();
                let mut want_preds = Vec::new();
                protos.classify_batch_into_with(be, &batch, &mut want_scores, &mut want_preds);
                for pool in &pools {
                    let t = pool.threads();
                    let mut got = vec![0i64; classes * w];
                    protos.scores_batch_into_pool(pool, be, &batch, &mut got);
                    assert_eq!(got, want, "batch scores drift d={d} C={classes} threads={t}");
                    let mut ps = Vec::new();
                    let mut pp = Vec::new();
                    protos.classify_batch_into_pool(pool, be, &batch, &mut ps, &mut pp);
                    assert_eq!(ps, want_scores, "pool scores buffer d={d} threads={t}");
                    assert_eq!(pp, want_preds, "pool preds d={d} threads={t}");
                    for qi in 0..w {
                        let q = batch.get(qi);
                        assert_eq!(
                            protos.scores_pool(pool, be, &q),
                            protos.scores_with(be, &q),
                            "class-block scores drift d={d} threads={t}"
                        );
                        assert_eq!(
                            protos.classify_pool(pool, be, &q),
                            protos.classify_with(be, &q),
                            "class-block classify drift d={d} threads={t}"
                        );
                    }
                }
                // The plain (auto-dispatch) entry points agree with the
                // explicit sequential backend walk at every size — above
                // or below the parallelism threshold.
                assert_eq!(protos.scores_batch(&batch), want);
                assert_eq!(protos.classify_batch(&batch), want_preds);
            }
        }
        // Degenerate shapes through the pool paths.
        let none = PackedAccumulator::new(0, 130).finalize();
        let pool = &pools[2];
        let mut batch = PackedBatch::new(130);
        batch.push(&PackedHypervector::random(130, &mut rng));
        let (mut s, mut p) = (Vec::new(), Vec::new());
        none.classify_batch_into_pool(pool, be, &batch, &mut s, &mut p);
        assert_eq!(p, vec![0]);
        assert!(s.is_empty());
    }

    /// Per-thread training accumulators merged in fixed order must equal
    /// one accumulator fed every sample sequentially — the property the
    /// parallel training bundling stands on — including plane-count
    /// mismatches (one side saw many more samples) and empty sides.
    #[test]
    fn accumulator_merge_matches_sequential_adds() {
        forall("accumulator-merge", PropConfig::default(), |rng, size| {
            let d = random_dim(rng, size.min(6));
            let classes = 1 + rng.gen_range(3);
            let n = rng.gen_range(2 * size.max(1) + 8);
            let members: Vec<(usize, PackedHypervector)> = (0..n)
                .map(|_| (rng.gen_range(classes), PackedHypervector::random(d, rng)))
                .collect();
            let mut seq = PackedAccumulator::new(classes, d);
            for (class, hv) in &members {
                seq.add(*class, hv);
            }
            // Split at a random point (possibly empty sides), add each
            // half into its own accumulator, merge left-to-right.
            let split = rng.gen_range(n + 1);
            let mut left = PackedAccumulator::new(classes, d);
            let mut right = PackedAccumulator::new(classes, d);
            for (i, (class, hv)) in members.iter().enumerate() {
                if i < split {
                    left.add(*class, hv);
                } else {
                    right.add(*class, hv);
                }
            }
            left.merge(&right);
            for class in 0..classes {
                for i in 0..d.min(150) {
                    crate::prop_assert!(
                        left.minus_count(class, i) == seq.minus_count(class, i),
                        "counter drift at class {class}, coord {i} (d={d}, split={split}/{n})"
                    );
                }
            }
            crate::prop_assert!(
                left.finalize() == seq.finalize(),
                "merged prototypes differ at d={d}, split={split}/{n}"
            );
            Ok(())
        });
    }

    /// The word-parallel bit-sliced finalize must agree with the per-bit
    /// threshold reconstructed from `minus_count` — the old scalar rule
    /// — at every count parity (ties → +1) and boundary dim.
    #[test]
    fn finalize_matches_per_bit_threshold_reference() {
        let mut rng = Xoshiro256::seed_from_u64(505);
        for &d in &[1usize, 63, 64, 65, 130] {
            // n spans odd/even and the zero-sample edge.
            for n in 0..12usize {
                let mut acc = PackedAccumulator::new(2, d);
                for i in 0..n {
                    acc.add(i % 2, &PackedHypervector::random(d, &mut rng));
                }
                let reference: Vec<PackedHypervector> = (0..2)
                    .map(|class| {
                        let mut p = PackedHypervector::zeros(d);
                        for i in 0..d {
                            if 2 * acc.minus_count(class, i) > acc.counts[class] {
                                p.words[i / WORD_BITS] |= 1 << (i % WORD_BITS);
                            }
                        }
                        p
                    })
                    .collect();
                let got = acc.finalize();
                assert_eq!(got.prototypes, reference, "finalize drift at d={d}, n={n}");
                for p in &got.prototypes {
                    assert!(tail_clean(p), "finalize leaked tail bits at d={d}");
                }
            }
        }
    }

    /// Deterministic spot-check of the same three kernels at the fixed
    /// word-boundary dims (63/64/65, 1000).
    #[test]
    fn backends_match_scalar_at_boundary_dims() {
        let scalar = simd::scalar();
        let mut rng = Xoshiro256::seed_from_u64(313);
        for &d in &[63usize, 64, 65, 1000] {
            let classes = 3;
            let mut scalar_acc = PackedAccumulator::new(classes, d);
            let members: Vec<(usize, PackedHypervector)> = (0..11)
                .map(|i| (i % classes, PackedHypervector::random(d, &mut rng)))
                .collect();
            for (class, hv) in &members {
                scalar_acc.add_with(scalar, *class, hv);
            }
            let protos = scalar_acc.finalize();
            let queries: Vec<PackedHypervector> = (0..5)
                .map(|_| PackedHypervector::random(d, &mut rng))
                .collect();
            let mut batch = PackedBatch::new(d);
            for q in &queries {
                batch.push(q);
            }
            let mut want = vec![0i64; classes * queries.len()];
            protos.scores_batch_into_with(scalar, &batch, &mut want);
            for be in simd::available() {
                let mut acc = PackedAccumulator::new(classes, d);
                for (class, hv) in &members {
                    acc.add_with(be, *class, hv);
                }
                assert_eq!(acc.finalize(), protos, "{} finalize d={d}", be.name());
                let mut got = vec![0i64; classes * queries.len()];
                protos.scores_batch_into_with(be, &batch, &mut got);
                assert_eq!(got, want, "{} batch scores d={d}", be.name());
                for q in &queries {
                    assert_eq!(
                        protos.classify_with(be, q),
                        protos.classify_with(scalar, q),
                        "{} classify d={d}",
                        be.name()
                    );
                    assert_eq!(
                        q.hamming_with(be, &queries[0]),
                        q.hamming_with(scalar, &queries[0]),
                        "{} hamming d={d}",
                        be.name()
                    );
                }
            }
        }
    }
}
