//! Mini property-based testing harness (the vendored crate set has no
//! `proptest`). `forall` runs a seeded-deterministic family of random
//! cases and, on failure, shrinks in two stages — same-seed size
//! reduction, then a budget of *fresh* seeds re-sampled at or below the
//! shrunken size — and reports the overall smallest reproduction with its
//! seed, a pragmatic subset of proptest's generate-and-shrink loop that
//! keeps failures reproducible (fixed base seed).
//!
//! CI can crank the case count without code edits via the
//! `NYSX_PROP_CASES` environment variable (overrides every property's
//! `PropConfig::cases` when set to a positive integer).

use crate::util::rng::{SplitMix64, Xoshiro256};

/// Configuration for a property run.
#[derive(Debug, Clone, Copy)]
pub struct PropConfig {
    pub cases: usize,
    pub base_seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        Self {
            cases: 32,
            base_seed: 0x9E3779B97F4A7C15,
        }
    }
}

/// Fresh seeds re-sampled per failure while hunting for a smaller
/// reproduction (stage 2 of the shrink loop).
const SHRINK_SEED_BUDGET: usize = 8;

/// Outcome of a single case.
pub type CaseResult = Result<(), String>;

/// Resolve the effective case count: `NYSX_PROP_CASES` (when it parses
/// to a positive integer) beats the per-property config.
fn resolve_cases(cfg: &PropConfig, env_override: Option<&str>) -> usize {
    env_override
        .and_then(|v| v.trim().parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or(cfg.cases)
}

/// The smallest reproduction found so far.
struct Repro {
    seed: u64,
    size: usize,
    msg: String,
}

/// Run `property(case_rng, size)` for the configured number of cases of
/// growing size. Panics with the smallest failing (seed, size) found so
/// the case can be replayed with `Xoshiro256::seed_from_u64(seed)`.
pub fn forall<F>(name: &str, cfg: PropConfig, mut property: F)
where
    F: FnMut(&mut Xoshiro256, usize) -> CaseResult,
{
    let cases = resolve_cases(&cfg, std::env::var("NYSX_PROP_CASES").ok().as_deref());
    for case in 0..cases {
        let seed = cfg.base_seed.wrapping_add(case as u64 * 0x9E3779B9);
        let mut rng = Xoshiro256::seed_from_u64(seed);
        // Sizes ramp up so early failures are small.
        let size = 1 + case * 4;
        if let Err(msg) = property(&mut rng, size) {
            let mut best = Repro { seed, size, msg };

            // Stage 1: same-seed shrink — smallest failing size for the
            // original seed (sizes scan up, so the first hit is minimal).
            for small in 1..size {
                let mut srng = Xoshiro256::seed_from_u64(seed);
                if let Err(m) = property(&mut srng, small) {
                    best = Repro {
                        seed,
                        size: small,
                        msg: m,
                    };
                    break;
                }
            }

            // Stage 2: re-sample a budget of fresh seeds, keeping only a
            // *strictly smaller* reproduction than the best so far.
            let mut seeder = SplitMix64::new(seed ^ 0xC0FF_EE00_D15E_A5E5);
            for _ in 0..SHRINK_SEED_BUDGET {
                let fresh = seeder.next_u64();
                for small in 1..best.size {
                    let mut frng = Xoshiro256::seed_from_u64(fresh);
                    if let Err(m) = property(&mut frng, small) {
                        best = Repro {
                            seed: fresh,
                            size: small,
                            msg: m,
                        };
                        break;
                    }
                }
            }

            if best.seed == seed && best.size == size {
                panic!(
                    "property '{name}' failed (seed={seed:#x}, size={size}): {}",
                    best.msg
                );
            }
            panic!(
                "property '{name}' failed (seed={:#x}, size={}, shrunk from seed={seed:#x}, size={size}): {}",
                best.seed, best.size, best.msg
            );
        }
    }
}

/// Assert-style helper for inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall("sum-commutes", PropConfig::default(), |rng, size| {
            let a: Vec<u32> = (0..size).map(|_| rng.next_u32() % 1000).collect();
            let fwd: u64 = a.iter().map(|&x| x as u64).sum();
            let rev: u64 = a.iter().rev().map(|&x| x as u64).sum();
            prop_assert!(fwd == rev, "sum mismatch {fwd} vs {rev}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_reports_seed() {
        forall(
            "always-fails",
            PropConfig {
                cases: 3,
                ..Default::default()
            },
            |_, _| Err("nope".to_string()),
        );
    }

    #[test]
    #[should_panic(expected = "size=4")]
    fn shrinks_to_smallest_failing_size() {
        // Fails for size >= 4. First failing scheduled case is size 5
        // (sizes ramp 1, 5, 9, ...); the shrink loop must land on 4.
        forall(
            "size-threshold",
            PropConfig {
                cases: 4,
                ..Default::default()
            },
            |_, size| {
                if size >= 4 {
                    Err(format!("too big: {size}"))
                } else {
                    Ok(())
                }
            },
        );
    }

    #[test]
    fn fresh_seed_resampling_finds_smaller_repro() {
        // Fails iff any of the `size` draws is divisible by 5. With the
        // default base seed the first scheduled failure is case 1
        // (size 5), whose own stream first hits a multiple of 5 at draw 3
        // — so same-seed shrinking bottoms out at size 3, and only the
        // fresh-seed stage can (and deterministically does) reach a
        // size-1 reproduction. (Outcome precomputed from the PRNG
        // definition; it changes only if the rng, base seed or shrink
        // constants change.)
        let result = std::panic::catch_unwind(|| {
            forall(
                "divisible-draw",
                PropConfig {
                    cases: 16,
                    ..Default::default()
                },
                |rng, size| {
                    for _ in 0..size {
                        if rng.next_u64() % 5 == 0 {
                            return Err("divisible draw".to_string());
                        }
                    }
                    Ok(())
                },
            );
        });
        let err = result.expect_err("property should fail");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(
            msg.contains("size=1, shrunk from"),
            "expected a fresh-seed size-1 repro, got: {msg}"
        );
    }

    #[test]
    fn env_override_beats_config() {
        let cfg = PropConfig {
            cases: 32,
            ..Default::default()
        };
        assert_eq!(resolve_cases(&cfg, None), 32);
        assert_eq!(resolve_cases(&cfg, Some("128")), 128);
        assert_eq!(resolve_cases(&cfg, Some(" 7 ")), 7);
        // Garbage and zero fall back to the config.
        assert_eq!(resolve_cases(&cfg, Some("lots")), 32);
        assert_eq!(resolve_cases(&cfg, Some("0")), 32);
    }
}
