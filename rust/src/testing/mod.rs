//! Mini property-based testing harness (the vendored crate set has no
//! `proptest`). `forall` runs a seeded-deterministic family of random
//! cases and, on failure, retries with the *smallest* failing case seen
//! among a shrink budget of re-samples — a pragmatic subset of proptest's
//! generate-and-shrink loop that keeps failures reproducible (fixed base
//! seed) and reported with their seed.

use crate::util::rng::Xoshiro256;

/// Configuration for a property run.
#[derive(Debug, Clone, Copy)]
pub struct PropConfig {
    pub cases: usize,
    pub base_seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        Self {
            cases: 32,
            base_seed: 0x9E3779B97F4A7C15,
        }
    }
}

/// Outcome of a single case.
pub type CaseResult = Result<(), String>;

/// Run `property(case_rng, size)` for `cfg.cases` cases of growing size.
/// Panics with the failing seed + message so the case can be replayed.
pub fn forall<F>(name: &str, cfg: PropConfig, mut property: F)
where
    F: FnMut(&mut Xoshiro256, usize) -> CaseResult,
{
    for case in 0..cfg.cases {
        let seed = cfg.base_seed.wrapping_add(case as u64 * 0x9E3779B9);
        let mut rng = Xoshiro256::seed_from_u64(seed);
        // Sizes ramp up so early failures are small.
        let size = 1 + case * 4;
        if let Err(msg) = property(&mut rng, size) {
            // Shrink-lite: re-run smaller sizes with the same seed to
            // report the smallest reproduction.
            for small in 1..size {
                let mut srng = Xoshiro256::seed_from_u64(seed);
                if property(&mut srng, small).is_err() {
                    panic!(
                        "property '{name}' failed (seed={seed:#x}, size={small}, shrunk from {size}): {msg}"
                    );
                }
            }
            panic!("property '{name}' failed (seed={seed:#x}, size={size}): {msg}");
        }
    }
}

/// Assert-style helper for inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall("sum-commutes", PropConfig::default(), |rng, size| {
            let a: Vec<u32> = (0..size).map(|_| rng.next_u32() % 1000).collect();
            let fwd: u64 = a.iter().map(|&x| x as u64).sum();
            let rev: u64 = a.iter().rev().map(|&x| x as u64).sum();
            prop_assert!(fwd == rev, "sum mismatch {fwd} vs {rev}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_reports_seed() {
        forall(
            "always-fails",
            PropConfig {
                cases: 3,
                ..Default::default()
            },
            |_, _| Err("nope".to_string()),
        );
    }
}
