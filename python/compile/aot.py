"""AOT export: lower the L2 jax graphs to HLO **text** artifacts the rust
runtime loads via ``HloModuleProto::from_text_file``.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5
emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (written to ``artifacts/``, indexed by ``manifest.json``):

* ``encode_<shape>.hlo.txt`` — the full Algorithm-1 graph at padded
  shapes (cross-layer equivalence tests + small-graph serving);
* ``nee_<d>x<s>.hlo.txt``    — the NEE projection alone (the hot-path
  artifact the coordinator can execute per request).

Usage: ``python -m compile.aot --out-dir ../artifacts`` (see Makefile).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    rust side unwraps with to_tuple1/to_tuple)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def export_encode(out_dir, n, f, hops, bmax, s, d, classes):
    name = f"encode_n{n}_f{f}_h{hops}_b{bmax}_s{s}_d{d}_c{classes}"
    lowered = jax.jit(model.encode_and_classify).lower(
        spec((n, n)),
        spec((n, f)),
        spec((n,)),
        spec((hops, f)),
        spec((hops,)),
        spec((), jnp.float32),
        spec((hops, bmax), jnp.int32),
        spec((hops, s, bmax)),
        spec((d, s)),
        spec((classes, d)),
    )
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as fh:
        fh.write(to_hlo_text(lowered))
    return {
        "name": name,
        "kind": "encode",
        "path": os.path.basename(path),
        "n": n,
        "f": f,
        "hops": hops,
        "bmax": bmax,
        "s": s,
        "d": d,
        "classes": classes,
    }


def export_nee(out_dir, d, s):
    name = f"nee_d{d}_s{s}"
    lowered = jax.jit(model.nee_only).lower(spec((d, s)), spec((s,)))
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as fh:
        fh.write(to_hlo_text(lowered))
    return {"name": name, "kind": "nee", "path": os.path.basename(path), "d": d, "s": s}


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    # Padded shapes for the full-graph artifact (test-scale defaults keep
    # `make artifacts` + the rust equivalence tests fast).
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--f", type=int, default=16)
    ap.add_argument("--hops", type=int, default=3)
    ap.add_argument("--bmax", type=int, default=512)
    ap.add_argument("--s", type=int, default=48)
    ap.add_argument("--d", type=int, default=2048)
    ap.add_argument("--classes", type=int, default=4)
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    entries = []
    entries.append(
        export_encode(
            args.out_dir, args.n, args.f, args.hops, args.bmax, args.s, args.d, args.classes
        )
    )
    # Hot-path NEE artifacts: the test-scale one plus the paper-scale
    # deployment point (d=10^4; s=448 covers every dataset's landmark
    # budget — the runtime zero-pads C and P_nys columns up to s).
    entries.append(export_nee(args.out_dir, args.d, args.s))
    entries.append(export_nee(args.out_dir, 10_000, 448))
    manifest = {"version": 1, "artifacts": entries}
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=2, sort_keys=True)
    print(f"wrote {len(entries)} artifacts + manifest to {args.out_dir}")


if __name__ == "__main__":
    main()
