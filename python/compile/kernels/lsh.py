"""L1 Pallas kernel: LSHU code generation (paper §5.2.1).

Computes ``c = floor((M @ u + b) / w)`` for a block of nodes at a time —
the DenseMV + quantize stage of the LSHU. Node features stream through
VMEM in (BLOCK_N, f) tiles; the projection vector ``u`` stays resident.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 128


def _lsh_block_kernel(m_ref, u_ref, bw_ref, o_ref):
    proj = m_ref[...] @ u_ref[...]
    b = bw_ref[0]
    w = bw_ref[1]
    o_ref[...] = jnp.floor((proj + b) / w).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_n",))
def lsh_codes(m, u, b, w, block_n=DEFAULT_BLOCK_N):
    """Integer LSH codes for every node.

    m: (n, f) float32; u: (f,) float32; b, w: python/array scalars.
    Returns (n,) int32.
    """
    n, f = m.shape
    block_n = min(block_n, max(8, n))
    pad = (-n) % block_n
    if pad:
        m = jnp.pad(m, ((0, pad), (0, 0)))
    np_ = n + pad
    bw = jnp.stack([jnp.asarray(b, jnp.float32), jnp.asarray(w, jnp.float32)])
    out = pl.pallas_call(
        _lsh_block_kernel,
        grid=(np_ // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, f), lambda i: (i, 0)),
            pl.BlockSpec((f,), lambda i: (0,)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((np_,), jnp.int32),
        interpret=True,
    )(m.astype(jnp.float32), u.astype(jnp.float32), bw)
    return out[:n]
