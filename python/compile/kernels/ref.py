"""Pure-jnp correctness oracles for the Pallas kernels (L1).

Every kernel in this package is checked against these references by
``python/tests/test_kernels.py`` (hypothesis sweeps shapes and dtypes).
The sign convention matches the rust functional model
(``Hypervector::from_real``): sign(0) := +1.
"""

import jax.numpy as jnp

INT_SENTINEL = jnp.iinfo(jnp.int32).max


def bipolar_sign(y):
    """sign with sign(0) := +1, emitting the input dtype."""
    return jnp.where(y < 0, -1.0, 1.0).astype(y.dtype)


def nee_ref(p_nys, c):
    """Nystrom Encoding Engine oracle: h = sign(P_nys @ C).

    p_nys: (d, s) float; c: (s,) float -> (d,) bipolar float32.
    """
    y = p_nys.astype(jnp.float32) @ c.astype(jnp.float32)
    return bipolar_sign(y)


def lsh_codes_ref(m, u, b, w):
    """LSH code oracle: floor((M @ u + b) / w) as int32.

    m: (n, f); u: (f,); b, w: scalars -> (n,) int32.
    """
    proj = m.astype(jnp.float32) @ u.astype(jnp.float32)
    return jnp.floor((proj + b) / w).astype(jnp.int32)


def histogram_ref(codes, codebook, node_mask):
    """Histogram oracle: bin codes through a sorted codebook.

    codes: (n,) int32; codebook: (bmax,) int32 sorted ascending, padded
    with INT_SENTINEL; node_mask: (n,) bool. Codes of masked-off nodes
    and codes absent from the codebook are skipped (Alg. 1 lines 6-8);
    masked nodes are remapped to the sentinel so they can only land in
    sentinel (zero-weight) bins.
    """
    codes = jnp.where(node_mask, codes, INT_SENTINEL)
    idx = jnp.searchsorted(codebook, codes)
    idx = jnp.clip(idx, 0, codebook.shape[0] - 1)
    valid = codebook[idx] == codes
    hist = jnp.zeros(codebook.shape[0], dtype=jnp.float32)
    return hist.at[idx].add(jnp.where(valid, 1.0, 0.0))
