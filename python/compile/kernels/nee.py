"""L1 Pallas kernel: the Nyström Encoding Engine (paper §5.2.5).

The FPGA streams ``P_nys`` (d×s FP32) from DDR through a 512-bit AXI port
into 16 MAC lanes, with the similarity vector ``C`` resident on chip and
``sign()`` fused into the accumulator drain. The TPU-shaped analogue
(DESIGN.md §5, "Hardware adaptation"):

* ``P_nys`` lives in HBM (the "DDR"); a ``BlockSpec`` of ``(BLOCK_D, s)``
  tiles it into VMEM — the HBM→VMEM block copy plays the AXI burst + FIFO
  role, and Pallas double-buffers consecutive blocks exactly like the
  paper's outstanding reads decouple fetch from compute.
* ``C`` is small and replicated into VMEM for every block (the paper's
  cyclically-partitioned on-chip buffer).
* Each block computes a (BLOCK_D, s) × (s,) product on the VPU/MXU and
  fuses bipolarization into the epilogue, so only ±1 values leave the
  kernel (the paper's ">4× on-chip buffer reduction" fusion).

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; real-TPU perf is estimated from the VMEM footprint + lane
utilization notes in DESIGN.md §5.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows of P_nys per VMEM block. 256 rows × s=512 × 4B = 512 KiB blocks —
# two in flight fit comfortably in 16 MiB VMEM while amortizing copy
# startup; a multiple of 8 sublanes. (Perf notes: DESIGN.md §5.)
DEFAULT_BLOCK_D = 256


def _nee_block_kernel(p_ref, c_ref, o_ref):
    """One (BLOCK_D, s) tile: fused project + bipolarize."""
    y = p_ref[...] @ c_ref[...]
    o_ref[...] = jnp.where(y < 0, -1.0, 1.0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_d",))
def nee_project_sign(p_nys, c, block_d=DEFAULT_BLOCK_D):
    """h = sign(P_nys @ C) via the streaming Pallas kernel.

    p_nys: (d, s) float32, c: (s,) float32 -> (d,) float32 in {-1, +1}.
    d is padded up to a multiple of ``block_d`` internally.
    """
    d, s = p_nys.shape
    (s2,) = c.shape
    assert s == s2, f"shape mismatch: {p_nys.shape} vs {c.shape}"
    block_d = min(block_d, max(8, d))
    pad = (-d) % block_d
    if pad:
        p_nys = jnp.pad(p_nys, ((0, pad), (0, 0)))
    dp = d + pad
    out = pl.pallas_call(
        _nee_block_kernel,
        grid=(dp // block_d,),
        in_specs=[
            # Stream one (block_d, s) tile of P_nys per grid step.
            pl.BlockSpec((block_d, s), lambda i: (i, 0)),
            # C is fully resident (same block every step).
            pl.BlockSpec((s,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_d,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((dp,), jnp.float32),
        interpret=True,
    )(p_nys.astype(jnp.float32), c.astype(jnp.float32))
    return out[:d]


def vmem_footprint_bytes(s, block_d=DEFAULT_BLOCK_D, double_buffered=True):
    """Estimated VMEM bytes for the chosen block shape (perf model).

    One P block + C + one output block, ×2 when double-buffered.
    """
    p_block = block_d * s * 4
    c_buf = s * 4
    o_block = block_d * 4
    mult = 2 if double_buffered else 1
    return mult * (p_block + o_block) + c_buf
