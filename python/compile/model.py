"""L2: the end-to-end Nyström-HDC inference graph in JAX (Algorithm 1),
calling the L1 Pallas kernels, with fixed (padded) shapes so it can be
AOT-lowered once and executed from the rust runtime.

All model parameters are runtime *inputs* (not baked constants): the rust
coordinator trains the model, packs the padded parameter tensors once, and
feeds them with each query — so a single HLO artifact serves any trained
model of matching maximum shapes.

Shape/padding conventions (see ``python/compile/aot.py`` for the manifest):

* graphs are padded to ``n`` nodes; ``node_mask`` flags real nodes; padded
  adjacency rows/cols are zero;
* per-hop codebooks are sorted int32 arrays padded with INT32_MAX, so
  padded nodes and padded codebook slots can only meet in sentinel bins
  whose ``hists`` columns are zero;
* ``hists`` is (hops, s, bmax) with zero columns for padding;
* outputs are the class scores (C,) and the bipolar HV (d,).
"""

import jax.numpy as jnp

from .kernels.nee import nee_project_sign
from .kernels.ref import INT_SENTINEL


def encode_and_classify(adj, feats, node_mask, u, b, w, codebooks, hists, p_nys, protos):
    """Algorithm 1 with fixed shapes.

    adj:       (n, n) float32 — 0/1 adjacency (symmetric, zero-padded)
    feats:     (n, f) float32 — node features (one-hot labels)
    node_mask: (n,)   float32 — 1.0 for real nodes, 0.0 for padding
    u:         (hops, f) float32 — LSH projections
    b:         (hops,) float32   — LSH offsets
    w:         ()      float32   — shared LSH width
    codebooks: (hops, bmax) int32 — sorted, INT32_MAX-padded codes
    hists:     (hops, s, bmax) float32 — landmark histogram matrices
    p_nys:     (d, s) float32 — Nyström projection
    protos:    (classes, d) float32 — bipolar class prototypes

    Returns (scores (classes,), hv (d,)).
    """
    hops = u.shape[0]
    s = hists.shape[1]
    c_vec = jnp.zeros((s,), jnp.float32)
    for t in range(hops):  # hops is static: unrolled at trace time
        # LSHU restructured chain (paper §5.2.1): proj = A^t (F u^(t)).
        proj = feats @ u[t]
        for _ in range(t):
            proj = adj @ proj
        codes = jnp.floor((proj + b[t]) / w).astype(jnp.int32)
        # MPHE-equivalent vocabulary lookup: padded nodes -> sentinel.
        codes = jnp.where(node_mask > 0, codes, INT_SENTINEL)
        cb = codebooks[t]
        idx = jnp.clip(jnp.searchsorted(cb, codes), 0, cb.shape[0] - 1)
        valid = cb[idx] == codes
        # HUE: histogram accumulation.
        hist = jnp.zeros((cb.shape[0],), jnp.float32)
        hist = hist.at[idx].add(jnp.where(valid, 1.0, 0.0))
        # KSE: v^(t) = H^(t) h^(t), accumulated into C.
        c_vec = c_vec + hists[t] @ hist
    # NEE (L1 Pallas kernel): h = sign(P_nys C), fused bipolarization.
    hv = nee_project_sign(p_nys, c_vec)
    # SCE: scores = G h (argmax stays on the rust side).
    scores = protos @ hv
    return scores, hv


def nee_only(p_nys, c_vec):
    """The NEE stage alone (the runtime's hot-path artifact)."""
    return (nee_project_sign(p_nys, c_vec),)


def example_inputs(n, f, hops, bmax, s, d, classes, seed=0):
    """Random, well-formed example inputs (tests + AOT example args)."""
    import numpy as np

    rng = np.random.default_rng(seed)
    n_real = max(2, n // 2)
    adj = np.zeros((n, n), np.float32)
    for _ in range(3 * n_real):
        i, j = rng.integers(0, n_real, 2)
        if i != j:
            adj[i, j] = 1.0
            adj[j, i] = 1.0
    feats = np.zeros((n, f), np.float32)
    feats[np.arange(n_real), rng.integers(0, f, n_real)] = 1.0
    node_mask = np.zeros((n,), np.float32)
    node_mask[:n_real] = 1.0
    u = rng.standard_normal((hops, f)).astype(np.float32)
    b = rng.uniform(0, 1, hops).astype(np.float32)
    w = np.float32(1.0)
    # Codebooks: sorted plausible code ranges with sentinel padding.
    codebooks = np.full((hops, bmax), INT_SENTINEL, np.int32)
    for t in range(hops):
        n_codes = int(rng.integers(bmax // 2, bmax))
        codes = np.unique(rng.integers(-50, 50, n_codes).astype(np.int32))
        codebooks[t, : codes.size] = np.sort(codes)
    hists = rng.poisson(0.3, (hops, s, bmax)).astype(np.float32)
    # Zero the sentinel columns.
    for t in range(hops):
        hists[t][:, codebooks[t] == INT_SENTINEL] = 0.0
    p_nys = (rng.standard_normal((d, s)) / np.sqrt(s)).astype(np.float32)
    protos = np.sign(rng.standard_normal((classes, d))).astype(np.float32)
    return adj, feats, node_mask, u, b, w, codebooks, hists, p_nys, protos
