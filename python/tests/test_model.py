"""L2 correctness: the jax inference graph vs an independent numpy
implementation of Algorithm 1, plus padding-invariance properties."""

import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from compile import model
from compile.kernels.ref import INT_SENTINEL

settings.register_profile("ci", max_examples=10, deadline=None)
settings.load_profile("ci")


def numpy_alg1(adj, feats, node_mask, u, b, w, codebooks, hists, p_nys, protos):
    """Independent numpy Algorithm 1 (baseline schedule M = A^t F)."""
    hops = u.shape[0]
    s = hists.shape[1]
    n_real = int((node_mask > 0).sum())
    a = adj[:n_real, :n_real].astype(np.float64)
    m = feats[:n_real].astype(np.float64)
    c_vec = np.zeros(s)
    for t in range(hops):
        proj = m @ u[t].astype(np.float64)
        codes = np.floor((proj + float(b[t])) / float(w)).astype(np.int64)
        vocab = {int(c): i for i, c in enumerate(codebooks[t]) if c != INT_SENTINEL}
        hist = np.zeros(hists.shape[2])
        for c in codes:
            if int(c) in vocab:
                hist[vocab[int(c)]] += 1
        c_vec += hists[t].astype(np.float64) @ hist
        if t + 1 < hops:
            m = a @ m
    y = p_nys.astype(np.float64) @ c_vec
    hv = np.where(y < 0, -1.0, 1.0)
    scores = protos.astype(np.float64) @ hv
    return scores, hv, c_vec


@given(seed=st.integers(0, 2**31 - 1))
def test_model_matches_numpy_alg1(seed):
    shapes = dict(n=24, f=6, hops=3, bmax=64, s=10, d=256, classes=3)
    inputs = model.example_inputs(**shapes, seed=seed)
    scores, hv = model.encode_and_classify(*[jnp.asarray(x) for x in inputs])
    want_scores, want_hv, _ = numpy_alg1(*inputs)
    # fp32 vs fp64 kernel-vector accumulation: HV signs can differ only
    # where |y| is at rounding scale; demand near-perfect agreement.
    agree = np.mean(np.asarray(hv) == want_hv)
    assert agree > 0.995, f"HV agreement {agree}"
    # Scores are dot products over d of mostly-equal bipolar vectors.
    np.testing.assert_allclose(
        np.asarray(scores), want_scores, atol=2 * shapes["d"] * 0.005 + 1e-6
    )


def test_padding_invariance():
    # Growing the node padding must not change the outputs at all.
    shapes = dict(n=16, f=5, hops=2, bmax=32, s=8, d=128, classes=2)
    inputs = model.example_inputs(**shapes, seed=11)
    scores_a, hv_a = model.encode_and_classify(*[jnp.asarray(x) for x in inputs])
    adj, feats, mask, *rest = inputs
    pad = 9
    adj_p = np.pad(adj, ((0, pad), (0, pad)))
    feats_p = np.pad(feats, ((0, pad), (0, 0)))
    mask_p = np.pad(mask, (0, pad))
    scores_b, hv_b = model.encode_and_classify(
        jnp.asarray(adj_p), jnp.asarray(feats_p), jnp.asarray(mask_p),
        *[jnp.asarray(x) for x in rest]
    )
    np.testing.assert_array_equal(np.asarray(hv_a), np.asarray(hv_b))
    np.testing.assert_array_equal(np.asarray(scores_a), np.asarray(scores_b))


def test_chain_equals_baseline_schedule():
    # The L2 graph uses the restructured chain A^t (F u); the numpy oracle
    # uses the baseline (A^t F) u. Their kernel-similarity vectors must
    # agree (checked indirectly above; here on C directly via nee bypass).
    shapes = dict(n=20, f=4, hops=3, bmax=48, s=6, d=64, classes=2)
    inputs = model.example_inputs(**shapes, seed=5)
    _, _, c_numpy = numpy_alg1(*inputs)
    # Recompute C through the jax graph by projecting with identity-ish
    # P_nys: use P = I_s padded into (d, s) to read C off the projection.
    adj, feats, mask, u, b, w, cbs, hists, _, protos = inputs
    # P rows j and s+j read off ±C_j: sign(+C_j) == +1 always (C >= 0),
    # and sign(-C_j) == -1 iff C_j > 0 (sign(0) := +1 distinguishes the
    # empty bins).
    s_dim = shapes["s"]
    p_probe = np.zeros((shapes["d"], s_dim), np.float32)
    p_probe[:s_dim, :] = np.eye(s_dim, dtype=np.float32)
    p_probe[s_dim : 2 * s_dim, :] = -np.eye(s_dim, dtype=np.float32)
    _, hv = model.encode_and_classify(
        jnp.asarray(adj), jnp.asarray(feats), jnp.asarray(mask), jnp.asarray(u),
        jnp.asarray(b), jnp.asarray(w), jnp.asarray(cbs), jnp.asarray(hists),
        jnp.asarray(p_probe), jnp.asarray(protos),
    )
    hv = np.asarray(hv)
    np.testing.assert_array_equal(hv[:s_dim], np.ones(s_dim))
    got_positive = hv[s_dim : 2 * s_dim] < 0
    np.testing.assert_array_equal(got_positive, c_numpy > 0)


def test_aot_exports_parse(tmp_path):
    # The AOT path must produce loadable HLO text with the entry module.
    from compile import aot

    entry = aot.export_nee(str(tmp_path), d=64, s=8)
    text = (tmp_path / entry["path"]).read_text()
    assert "ENTRY" in text and "HloModule" in text
    entry2 = aot.export_encode(str(tmp_path), n=8, f=3, hops=2, bmax=16, s=4, d=32, classes=2)
    text2 = (tmp_path / entry2["path"]).read_text()
    assert "ENTRY" in text2
