"""Pytest bootstrap: make the `compile` package importable when the suite
is run from the repository root (`python -m pytest python/tests -q`)."""

import os
import sys

_PYTHON_DIR = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _PYTHON_DIR not in sys.path:
    sys.path.insert(0, _PYTHON_DIR)
