"""L1 correctness: Pallas kernels vs the pure-jnp oracles in ref.py.

Hypothesis sweeps shapes, block sizes and value distributions; the
comparisons are exact (same fp32 ops, same sign convention).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from compile.kernels.lsh import lsh_codes
from compile.kernels.nee import nee_project_sign, vmem_footprint_bytes
from compile.kernels.ref import bipolar_sign, histogram_ref, lsh_codes_ref, nee_ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


# ---------------------------------------------------------------- NEE ----


@given(
    d=st.integers(1, 700),
    s=st.integers(1, 64),
    block_d=st.sampled_from([8, 64, 128, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_nee_matches_ref(d, s, block_d, seed):
    rng = np.random.default_rng(seed)
    p = rng.standard_normal((d, s)).astype(np.float32)
    c = rng.standard_normal((s,)).astype(np.float32)
    got = nee_project_sign(jnp.asarray(p), jnp.asarray(c), block_d=block_d)
    want = nee_ref(jnp.asarray(p), jnp.asarray(c))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert got.shape == (d,)
    assert set(np.unique(np.asarray(got))) <= {-1.0, 1.0}


def test_nee_sign_zero_is_plus_one():
    # Zero projection (C = 0) must emit +1 everywhere — the rust
    # Hypervector::from_real convention.
    p = jnp.ones((16, 4), jnp.float32)
    c = jnp.zeros((4,), jnp.float32)
    out = np.asarray(nee_project_sign(p, c))
    np.testing.assert_array_equal(out, np.ones(16, np.float32))


def test_nee_nonmultiple_padding():
    # d not a multiple of the block: padding must not leak into output.
    rng = np.random.default_rng(7)
    p = rng.standard_normal((257, 5)).astype(np.float32)
    c = rng.standard_normal((5,)).astype(np.float32)
    got = np.asarray(nee_project_sign(jnp.asarray(p), jnp.asarray(c), block_d=128))
    want = np.asarray(nee_ref(jnp.asarray(p), jnp.asarray(c)))
    np.testing.assert_array_equal(got, want)


def test_vmem_footprint_within_budget():
    # The chosen deployment block shape must fit comfortably in 16 MiB
    # VMEM with double buffering (paper-scale s=448).
    assert vmem_footprint_bytes(448) < 4 * 1024 * 1024


# ---------------------------------------------------------------- LSH ----


@given(
    n=st.integers(1, 300),
    f=st.integers(1, 40),
    w=st.floats(0.05, 10.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_lsh_matches_ref(n, f, w, seed):
    rng = np.random.default_rng(seed)
    m = rng.standard_normal((n, f)).astype(np.float32)
    u = rng.standard_normal((f,)).astype(np.float32)
    b = np.float32(rng.uniform(0, w))
    got = lsh_codes(jnp.asarray(m), jnp.asarray(u), b, np.float32(w))
    want = lsh_codes_ref(jnp.asarray(m), jnp.asarray(u), b, np.float32(w))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_lsh_offset_shifts_codes_by_one():
    rng = np.random.default_rng(3)
    m = rng.standard_normal((20, 6)).astype(np.float32)
    u = rng.standard_normal((6,)).astype(np.float32)
    a = np.asarray(lsh_codes(jnp.asarray(m), jnp.asarray(u), np.float32(0.0), np.float32(1.0)))
    bshift = np.asarray(
        lsh_codes(jnp.asarray(m), jnp.asarray(u), np.float32(1.0), np.float32(1.0))
    )
    np.testing.assert_array_equal(a + 1, bshift)


# ---------------------------------------------------------- histogram ----


@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 200), bmax=st.integers(4, 64))
def test_histogram_ref_counts(seed, n, bmax):
    rng = np.random.default_rng(seed)
    codes = rng.integers(-10, 10, n).astype(np.int32)
    mask = rng.random(n) < 0.8
    from compile.kernels.ref import INT_SENTINEL

    cb = np.full(bmax, INT_SENTINEL, np.int32)
    vocab = np.unique(rng.integers(-10, 10, bmax // 2).astype(np.int32))[: bmax - 1]
    cb[: vocab.size] = vocab
    hist = np.asarray(histogram_ref(jnp.asarray(codes), jnp.asarray(cb), jnp.asarray(mask)))
    # Oracle-of-the-oracle: plain python counting.
    want = np.zeros(bmax, np.float32)
    lookup = {int(c): i for i, c in enumerate(vocab)}
    for c, m in zip(codes, mask):
        if m and int(c) in lookup:
            want[lookup[int(c)]] += 1
    np.testing.assert_array_equal(hist[: vocab.size], want[: vocab.size])
    # Masked-off nodes are remapped to the sentinel and land in the FIRST
    # sentinel bin (zero-weight in the landmark hists); all later sentinel
    # bins must be empty.
    if vocab.size < bmax:
        assert hist[vocab.size] == (~mask).sum()
        assert hist[vocab.size + 1 :].sum() == 0


def test_bipolar_sign_convention():
    y = jnp.asarray([-2.0, -0.0, 0.0, 3.0], jnp.float32)
    np.testing.assert_array_equal(np.asarray(bipolar_sign(y)), [-1.0, 1.0, 1.0, 1.0])
