"""Use `hypothesis` when available; otherwise fall back to a tiny
seeded-deterministic sampler with the same surface (`given`, `settings`,
`st.integers/floats/sampled_from`) so the property suites still run in
environments without the dependency (mirroring the rust side's in-repo
`testing::forall` harness). The fallback draws `max_examples` random
cases from a fixed per-test seed and reports the failing case's kwargs —
no shrinking, but fully reproducible.
"""

try:  # pragma: no cover - exercised only where hypothesis is installed
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised in dep-free containers
    import functools
    import inspect
    import random
    import zlib

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def sample(self, rng):
            return self._sample(rng)

    class st:  # noqa: N801 - mimics `hypothesis.strategies` module name
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

    class _Profile:
        def __init__(self, max_examples=20, **_ignored):
            self.max_examples = max_examples

    class settings:  # noqa: N801 - mimics `hypothesis.settings`
        _profiles = {}
        _current = _Profile()

        def __init__(self, **_ignored):
            pass

        @classmethod
        def register_profile(cls, name, **kwargs):
            cls._profiles[name] = _Profile(**kwargs)

        @classmethod
        def load_profile(cls, name):
            cls._current = cls._profiles.get(name, _Profile())

    def given(**strategies_by_arg):
        def decorate(fn):
            @functools.wraps(fn)
            def wrapper():
                # Per-test deterministic seed so failures replay.
                rng = random.Random(zlib.crc32(fn.__name__.encode()))
                for _ in range(settings._current.max_examples):
                    kwargs = {
                        name: strat.sample(rng)
                        for name, strat in strategies_by_arg.items()
                    }
                    try:
                        fn(**kwargs)
                    except Exception as exc:
                        raise AssertionError(
                            f"property {fn.__name__} failed for {kwargs!r}: {exc}"
                        ) from exc

            # pytest introspects signatures (via __wrapped__) to resolve
            # fixtures; present a zero-arg test, not the property args.
            wrapper.__dict__.pop("__wrapped__", None)
            wrapper.__signature__ = inspect.Signature()
            return wrapper

        return decorate
