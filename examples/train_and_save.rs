//! Train both model variants (NysHD uniform, NysX hybrid-DPP) on one
//! dataset, persist them with the binary model format, reload through
//! the facade, and verify behavioural equality — the offline half of the
//! deployment story. An unknown dataset name, a malformed flag, or a
//! corrupt artifact surfaces as a typed `NysxError`, not a panic.
//!
//!     cargo run --release --example train_and_save -- --dataset COX2

use std::path::Path;

use nysx::api::{NysxError, Pipeline};
use nysx::nystrom::LandmarkStrategy;
use nysx::util::cli::Args;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e}");
        std::process::exit(2);
    }
}

fn run() -> Result<(), NysxError> {
    let args = Args::from_env();
    let name = args.get_or("dataset", "COX2");
    let scale = args.try_f64("scale", 1.0).map_err(NysxError::Config)?;

    let out_dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("results/models");
    std::fs::create_dir_all(&out_dir)?;

    for (tag, strategy) in [
        ("nyshd", LandmarkStrategy::Uniform),
        ("nysx", LandmarkStrategy::HybridDpp { pool_factor: 2 }),
    ] {
        let t0 = std::time::Instant::now();
        let mut trained = Pipeline::for_dataset(name)?
            .scale(scale)
            .seed(42)
            .hv_dim(10_000)
            .landmarks(strategy)
            .train()?;
        let acc = trained.evaluate();
        let train_secs = t0.elapsed().as_secs_f64();
        let path = out_dir.join(format!(
            "{}_{tag}.nysx",
            trained.dataset().name.to_lowercase()
        ));
        trained.save(&path)?;
        let bytes = std::fs::metadata(&path)?.len();
        println!(
            "{tag:>6}: s={:<4} acc={}  train {train_secs:.1}s  artifact {:.1} MB -> {}",
            trained.model().s(),
            acc.map_or("n/a".to_string(), |a| format!("{:.1}%", 100.0 * a)),
            bytes as f64 / 1048576.0,
            path.display()
        );

        // Reload through the facade and verify bit-identical inference.
        // `reload` reuses this pipeline's dataset (no regeneration).
        let mut back = trained.reload(&path)?;
        let (ds, engine) = trained.parts();
        for (g, _) in ds.test.iter().take(16) {
            assert_eq!(
                engine.infer(g).hv,
                back.infer(g).hv,
                "roundtrip changed the model"
            );
        }
        println!("        reload verified: bit-identical HVs on 16 queries");
    }
    Ok(())
}
