//! Train both model variants (NysHD uniform, NysX hybrid-DPP) on one
//! dataset, persist them with the binary model format, reload, and verify
//! behavioural equality — the offline half of the deployment story.
//!
//!     cargo run --release --example train_and_save -- --dataset COX2

use nysx::infer::NysxEngine;
use nysx::model::io::{load_file, save_file};
use nysx::model::train::{evaluate, train};
use nysx::model::ModelConfig;
use nysx::nystrom::LandmarkStrategy;
use nysx::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let name = args.get_or("dataset", "COX2");
    let scale = args.get_f64("scale", 1.0);
    let spec = nysx::graph::tudataset::spec_by_name(name).expect("unknown dataset");
    let (ds, s_uni, s_dpp) = spec.generate_scaled(42, scale);

    let out_dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("results/models");
    std::fs::create_dir_all(&out_dir).expect("mkdir");

    for (tag, s, strategy) in [
        ("nyshd", s_uni, LandmarkStrategy::Uniform),
        ("nysx", s_dpp, LandmarkStrategy::HybridDpp { pool_factor: 2 }),
    ] {
        let cfg = ModelConfig {
            hops: spec.hops,
            hv_dim: 10_000,
            num_landmarks: s,
            strategy,
            ..ModelConfig::default()
        };
        let t0 = std::time::Instant::now();
        let model = train(&ds, &cfg);
        let acc = evaluate(&model, &ds.test);
        let path = out_dir.join(format!("{}_{tag}.nysx", ds.name.to_lowercase()));
        save_file(&model, &path).expect("save");
        let bytes = std::fs::metadata(&path).unwrap().len();
        println!(
            "{tag:>6}: s={s:<4} acc={:.1}%  train {:.1}s  artifact {:.1} MB -> {}",
            100.0 * acc,
            t0.elapsed().as_secs_f64(),
            bytes as f64 / 1048576.0,
            path.display()
        );

        // Reload and verify bit-identical inference.
        let back = load_file(&path).expect("load");
        let mut e1 = NysxEngine::new(&model);
        let mut e2 = NysxEngine::new(&back);
        for (g, _) in ds.test.iter().take(16) {
            assert_eq!(e1.infer(g).hv, e2.infer(g).hv, "roundtrip changed the model");
        }
        println!("        reload verified: bit-identical HVs on 16 queries");
    }
}
