//! Quickstart: train a Nyström-HDC classifier on a synthetic TUDataset,
//! classify the test split, and report accuracy plus simulated edge-FPGA
//! latency/energy for a single query — the 60-second tour of the public
//! API (`nysx::api`).
//!
//!     cargo run --release --example quickstart
//!
//! Training and inference run their heavy kernels on the `nysx::exec`
//! data-parallel pool. Size it with the `NYSX_THREADS` environment
//! variable (the `nysx` CLI also takes `--threads N`), or pin a
//! pipeline to its own pool with `.threads(n)` on the builder — results
//! are bit-identical at any thread count, only wall-clock changes:
//!
//!     NYSX_THREADS=4 cargo run --release --example quickstart

use nysx::api::{NysxError, Pipeline};
use nysx::sim::{simulate, AcceleratorConfig, PowerModel, SimOptions};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e}");
        std::process::exit(2);
    }
}

fn run() -> Result<(), NysxError> {
    // 1+2. Build and train through the facade: MUTAG-like synthetic
    //    graphs (Table 4 statistics), hybrid Uniform+DPP landmark
    //    selection (Alg. 2) at the reduced budget — the builder default —
    //    and d = 10^4 bipolar HVs.
    let t0 = std::time::Instant::now();
    let mut pipeline = Pipeline::for_dataset("MUTAG")?
        .hv_dim(10_000)
        .seed(42)
        .train()?;
    let model = pipeline.model().clone();
    println!(
        "dataset {}: {} train / {} test graphs",
        pipeline.dataset().name,
        pipeline.dataset().train.len(),
        pipeline.dataset().test.len()
    );
    println!(
        "trained in {:.1}s: s={} landmarks, {} hop codebooks, P_nys {}x{}",
        t0.elapsed().as_secs_f64(),
        model.s(),
        model.hops(),
        model.d(),
        model.s()
    );

    // 3. Accuracy (Fig 7 metric).
    match pipeline.evaluate() {
        Some(acc) => println!("test accuracy: {:.1}%", 100.0 * acc),
        None => println!("test accuracy: n/a (empty test split)"),
    }

    // 4. One inference through the owned engine, with the ZCU104 cycle
    //    model attached (Table 6/7 metrics).
    let (ds, engine) = pipeline.parts();
    let (graph, label) = &ds.test[0];
    let result = engine.infer(graph);
    let accel = AcceleratorConfig::zcu104();
    let breakdown = simulate(&result.trace, &accel, SimOptions::default());
    let energy = PowerModel::default().energy(&breakdown, &accel);
    println!(
        "query graph: {} nodes, {} edges -> class {} (truth {})",
        graph.num_nodes(),
        graph.num_edges(),
        result.predicted,
        label
    );
    println!(
        "simulated ZCU104: {:.3} ms, {:.2} mJ, {:.2} W (NEE {:.0}% of cycles)",
        energy.time_ms,
        energy.energy_mj,
        energy.avg_power_w,
        100.0 * breakdown.nee_fraction()
    );

    // 5. Model memory accounting (Table 2 / Table 8 metric).
    let mem = model.memory_report();
    println!(
        "model memory: {:.2} MB (P_nys = {:.0}% — streamed from DDR)",
        mem.total_dense() as f64 / 1048576.0,
        100.0 * mem.p_nys_fraction()
    );
    Ok(())
}
