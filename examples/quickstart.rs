//! Quickstart: train a Nyström-HDC classifier on a synthetic TUDataset,
//! classify the test split, and report accuracy plus simulated edge-FPGA
//! latency/energy for a single query — the 60-second tour of the public
//! API.
//!
//!     cargo run --release --example quickstart

use nysx::graph::tudataset::spec_by_name;
use nysx::infer::NysxEngine;
use nysx::model::train::{evaluate, train};
use nysx::model::ModelConfig;
use nysx::nystrom::LandmarkStrategy;
use nysx::sim::{simulate, AcceleratorConfig, PowerModel, SimOptions};

fn main() {
    // 1. A dataset: MUTAG-like synthetic graphs (Table 4 statistics).
    let spec = spec_by_name("MUTAG").unwrap();
    let ds = spec.generate(42);
    println!("dataset {}: {} train / {} test graphs", ds.name, ds.train.len(), ds.test.len());

    // 2. Train NysX: hybrid Uniform+DPP landmark selection (Alg. 2) at
    //    the reduced landmark budget, d = 10^4 bipolar HVs.
    let cfg = ModelConfig {
        hops: spec.hops,
        hv_dim: 10_000,
        num_landmarks: spec.s_dpp,
        strategy: LandmarkStrategy::HybridDpp { pool_factor: 2 },
        ..ModelConfig::default()
    };
    let t0 = std::time::Instant::now();
    let model = train(&ds, &cfg);
    println!(
        "trained in {:.1}s: s={} landmarks, {} hop codebooks, P_nys {}x{}",
        t0.elapsed().as_secs_f64(),
        model.s(),
        model.hops(),
        model.d(),
        model.s()
    );

    // 3. Accuracy (Fig 7 metric).
    println!("test accuracy: {:.1}%", 100.0 * evaluate(&model, &ds.test));

    // 4. One inference through the optimized engine, with the ZCU104
    //    cycle model attached (Table 6/7 metrics).
    let mut engine = NysxEngine::new(&model);
    let (graph, label) = &ds.test[0];
    let result = engine.infer(graph);
    let accel = AcceleratorConfig::zcu104();
    let breakdown = simulate(&result.trace, &accel, SimOptions::default());
    let energy = PowerModel::default().energy(&breakdown, &accel);
    println!(
        "query graph: {} nodes, {} edges -> class {} (truth {})",
        graph.num_nodes(),
        graph.num_edges(),
        result.predicted,
        label
    );
    println!(
        "simulated ZCU104: {:.3} ms, {:.2} mJ, {:.2} W (NEE {:.0}% of cycles)",
        energy.time_ms,
        energy.energy_mj,
        energy.avg_power_w,
        100.0 * breakdown.nee_fraction()
    );

    // 5. Model memory accounting (Table 2 / Table 8 metric).
    let mem = model.memory_report();
    println!(
        "model memory: {:.2} MB (P_nys = {:.0}% — streamed from DDR)",
        mem.total_dense() as f64 / 1048576.0,
        100.0 * mem.p_nys_fraction()
    );
}
