//! Regenerates EVERY table and figure of the paper's evaluation section
//! (Tables 3/4/6/7/8, Figures 6/7/8, the §5.2.5 roofline) on the
//! synthetic TUDataset suite, writing the report to
//! `results/full_evaluation.txt` and the per-dataset JSON to
//! `results/cache/`. The Fig 7 accuracy rows all come from the
//! `nysx::api::Classifier` dispatch path — NysX, NysHD and GraphHD are
//! scored by the exact same loop.
//!
//!     cargo run --release --example full_evaluation [-- --scale 0.25 --ablation]
//!
//! At scale 1.0 this trains 3 models × 8 datasets and takes a few
//! minutes; the JSON cache makes reruns and the `cargo bench` targets
//! instant.

use nysx::api::NysxError;
use nysx::bench::tables::*;
use nysx::util::cli::Args;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e}");
        std::process::exit(2);
    }
}

fn run() -> Result<(), NysxError> {
    let args = Args::from_env();
    let cfg = EvalConfig {
        scale: args
            .try_f64("scale", EvalConfig::default().scale)
            .map_err(NysxError::Config)?,
        seed: args.try_u64("seed", 42).map_err(NysxError::Config)?,
        hv_dim: args.try_usize("d", 10_000).map_err(NysxError::Config)?,
        ablation: args.get_bool("ablation"),
    };
    eprintln!(
        "full evaluation: scale={} seed={} d={}",
        cfg.scale, cfg.seed, cfg.hv_dim
    );
    let t0 = std::time::Instant::now();
    let evals = evaluate_all(&cfg);

    let mut report = String::new();
    report.push_str(&format!(
        "NysX full evaluation (scale={}, seed={}, d={})\ngenerated in {:.1}s\n\n",
        cfg.scale,
        cfg.seed,
        cfg.hv_dim,
        t0.elapsed().as_secs_f64()
    ));
    for section in [
        render_table4(&evals),
        render_table3(&evals),
        render_table6(&evals),
        render_fig6(&evals),
        render_table7(&evals),
        render_fig7(&evals),
        render_table8(&evals),
        render_fig8(&evals),
        render_roofline(),
    ] {
        report.push_str(&section);
        report.push('\n');
    }
    println!("{report}");
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("results");
    std::fs::create_dir_all(&out)?;
    let path = out.join("full_evaluation.txt");
    std::fs::write(&path, &report)?;
    eprintln!("report written to {}", path.display());
    Ok(())
}
