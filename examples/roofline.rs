//! §5.2.5 roofline analysis of the Nyström Encoding Engine: arithmetic
//! intensity vs machine balance across lane counts, the streamed vs
//! unstreamed cycle cost, and the FIFO-depth sensitivity — the analysis
//! that justifies the paper's streaming architecture.
//!
//!     cargo run --release --example roofline

use nysx::sim::engines::nee;
use nysx::sim::{nee_point, AcceleratorConfig};
use nysx::util::table::Table;

fn main() {
    println!("{}", nysx::bench::tables::render_roofline());

    // Streamed vs unstreamed transfer at the deployment point.
    let cfg = AcceleratorConfig::zcu104();
    let (d, s) = (10_000, 206); // NCI1 DPP deployment
    let mut t = Table::new("NEE transfer strategies (d=10000, s=206, ZCU104)")
        .header(&["strategy", "cycles", "ms @300MHz", "achieved GOPS"]);
    let streamed = nee::cycles(d, s, &cfg);
    let unstreamed = nee::cycles_unstreamed(d, s, &cfg);
    for (name, cycles) in [("512-bit streamed bursts", streamed), ("32-bit narrow reads", unstreamed)] {
        t.row(&[
            name.to_string(),
            cycles.to_string(),
            format!("{:.3}", cfg.cycles_to_ms(cycles)),
            format!("{:.2}", nysx::sim::roofline::achieved_gops(d, s, cycles, &cfg)),
        ]);
    }
    t.print();
    println!(
        "streaming speedup: {:.1}x (the paper's Challenge #2 motivation)\n",
        unstreamed as f64 / streamed as f64
    );

    // Sensitivity: the roofline says adding lanes beyond the machine
    // balance point buys nothing — show the attainable curve.
    let mut t = Table::new("Attainable NEE GOPS vs MAC lanes (memory wall)")
        .header(&["lanes", "peak GOPS", "attainable GOPS", "bound"]);
    for lanes in [4usize, 8, 16, 29, 32, 64, 128] {
        let mut c = cfg;
        c.nee_lanes = lanes;
        let p = nee_point(&c);
        t.row(&[
            lanes.to_string(),
            format!("{:.1}", p.peak_gops),
            format!("{:.2}", p.attainable_gops),
            format!("{:?}", p.bound),
        ]);
    }
    t.print();
    println!("=> beyond ~29 lanes (machine balance) the NEE is DDR-bound: more MACs are wasted.");
}
