//! END-TO-END DRIVER: the full three-layer system on a real workload.
//!
//! Trains a NysX model on the BZR synthetic dataset (paper-size) through
//! the `nysx::api` facade, starts the L3 serving coordinator (router →
//! batch queues → worker pool), replays the test split as a Poisson
//! request stream at a target rate, and reports the paper's serving
//! metrics: batch-1 latency (host + simulated ZCU104), throughput, and
//! energy per graph. When built with `--features xla-runtime` (and after
//! `make artifacts`), it finally runs the same queries through the
//! AOT-compiled XLA artifact (L2+L1 exported from jax, loaded via PJRT)
//! and cross-checks the predictions — proving all three layers compose.
//! The paper-vs-measured record lives in DESIGN.md §4.
//!
//!     cargo run --release --example edge_serving
//!     make artifacts && cargo run --release --features xla-runtime --example edge_serving

use std::collections::HashMap;

use nysx::api::{NysxError, Pipeline, TrainedPipeline};
use nysx::coordinator::{BatcherConfig, RoutingPolicy, ServerConfig, ShardedConfig, SubmitError};
use nysx::util::cli::Args;
use nysx::util::rng::Xoshiro256;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e}");
        std::process::exit(2);
    }
}

fn run() -> Result<(), NysxError> {
    let args = Args::from_env();
    let dataset = args.get_or("dataset", "BZR");
    let workers = args.try_usize("workers", 4).map_err(NysxError::Config)?;
    let requests = args.try_usize("requests", 2000).map_err(NysxError::Config)?;
    let rate_rps = args.try_f64("rate", 2000.0).map_err(NysxError::Config)?;
    let scale = args.try_f64("scale", 1.0).map_err(NysxError::Config)?;
    // --batch N > 1 lets workers pop whole batches and run one blocked
    // C×W SCE pass per batch (1 = the paper's real-time edge mode).
    let batch = args.try_usize("batch", 1).map_err(NysxError::Config)?.max(1);
    // --shards N > 1 replays through the sharded tier (consistent-hash
    // router in front of N independent coordinators) instead of the
    // single server. Predictions are bit-identical either way.
    let shards = args.try_usize("shards", 1).map_err(NysxError::Config)?;

    eprintln!("[1/4] training NysX on {dataset} (hybrid DPP, scale {scale})...");
    let t0 = std::time::Instant::now();
    let mut trained = Pipeline::for_dataset(dataset)?
        .scale(scale)
        .seed(42)
        .hv_dim(10_000)
        .train()?;
    let acc = trained.evaluate();
    eprintln!(
        "      trained in {:.1}s, test accuracy {}",
        t0.elapsed().as_secs_f64(),
        acc.map_or("n/a".to_string(), |a| format!("{:.1}%", 100.0 * a))
    );

    if shards > 1 {
        return run_sharded(&mut trained, shards, workers, requests, rate_rps, batch);
    }

    eprintln!("[2/4] starting coordinator: {workers} workers, size-aware routing, batch={batch}");
    let mut server = trained.serve(ServerConfig {
        workers,
        routing: RoutingPolicy::SizeAware,
        batcher: BatcherConfig {
            batch_size: batch,
            ..Default::default()
        },
        ..Default::default()
    })?;

    eprintln!("[3/4] replaying {requests} requests at ~{rate_rps:.0} req/s (Poisson arrivals)");
    let ds = trained.dataset();
    let mut rng = Xoshiro256::seed_from_u64(7);
    let mut truths = Vec::with_capacity(requests);
    // Responses received while absorbing backpressure mid-replay — they
    // must count toward the final tallies, not vanish.
    let mut responses = Vec::with_capacity(requests);
    let t_start = std::time::Instant::now();
    let mut next_arrival = 0.0f64;
    for _ in 0..requests {
        // Poisson process: exponential inter-arrival gaps.
        next_arrival += -rng.next_f64().max(1e-12).ln() / rate_rps;
        let target = std::time::Duration::from_secs_f64(next_arrival);
        while t_start.elapsed() < target {
            std::hint::spin_loop();
        }
        let idx = rng.gen_range(ds.test.len());
        truths.push(ds.test[idx].1);
        let mut graph = ds.test[idx].0.clone();
        loop {
            match server.submit(graph) {
                Ok(_) => break,
                Err(SubmitError::Backpressure(g)) => {
                    // Free a slot, keep the response, then retry.
                    graph = g;
                    responses.extend(server.recv());
                }
                Err(e @ SubmitError::Closed(_)) => return Err(e.into()),
            }
        }
    }
    responses.extend(server.drain());
    let wall = t_start.elapsed().as_secs_f64();
    assert_eq!(responses.len(), requests, "lost responses");
    let correct = responses
        .iter()
        .filter(|r| r.predicted == truths[r.id as usize])
        .count();
    let m = server.metrics();
    println!(
        "\n=== edge serving report ({} on {} workers) ===",
        ds.name, workers
    );
    println!("batch size          {batch}");
    println!(
        "requests            {requests} in {wall:.2}s -> {:.0} req/s",
        requests as f64 / wall
    );
    println!(
        "served accuracy     {:.1}%",
        100.0 * correct as f64 / requests.max(1) as f64
    );
    println!(
        "host latency (µs)   p50={:.0} p95={:.0} p99={:.0} max={:.0}",
        m.host_us.p50, m.host_us.p95, m.host_us.p99, m.host_us.max
    );
    println!(
        "queue wait (µs)     p50={:.0} p99={:.0}",
        m.queue_us.p50, m.queue_us.p99
    );
    println!(
        "sim ZCU104 latency  mean={:.3}ms p99={:.3}ms  (paper Table 6 band: 0.3-1.8ms)",
        m.fpga_ms.mean, m.fpga_ms.p99
    );
    println!(
        "sim ZCU104 energy   {:.2} mJ/graph mean  (paper Table 7 band: 0.2-1.3 mJ)",
        m.total_fpga_mj / requests.max(1) as f64
    );
    println!("per-worker          {:?}", m.per_worker);
    server.shutdown();

    xla_cross_check(&mut trained);
    Ok(())
}

/// The same Poisson replay against the sharded tier: a consistent-hash
/// front router spreads requests over `shards` independent coordinators
/// (each with its own exec pool and replicated prototypes). Shard ids
/// are strided per shard, so truths are keyed by the returned request
/// id instead of submission order.
fn run_sharded(
    trained: &mut TrainedPipeline,
    shards: usize,
    workers: usize,
    requests: usize,
    rate_rps: f64,
    batch: usize,
) -> Result<(), NysxError> {
    eprintln!("[2/4] starting sharded tier: {shards} shards x {workers} workers, batch={batch}");
    let mut tier = trained.serve_sharded(ShardedConfig {
        shards,
        per_shard: ServerConfig {
            workers,
            routing: RoutingPolicy::SizeAware,
            batcher: BatcherConfig {
                batch_size: batch,
                ..Default::default()
            },
            ..Default::default()
        },
        ..Default::default()
    })?;

    eprintln!("[3/4] replaying {requests} requests at ~{rate_rps:.0} req/s (Poisson arrivals)");
    let ds = trained.dataset();
    let mut rng = Xoshiro256::seed_from_u64(7);
    let mut truth_of: HashMap<u64, usize> = HashMap::with_capacity(requests);
    let mut responses = Vec::with_capacity(requests);
    let t_start = std::time::Instant::now();
    let mut next_arrival = 0.0f64;
    for _ in 0..requests {
        next_arrival += -rng.next_f64().max(1e-12).ln() / rate_rps;
        let target = std::time::Duration::from_secs_f64(next_arrival);
        while t_start.elapsed() < target {
            std::hint::spin_loop();
        }
        let idx = rng.gen_range(ds.test.len());
        let mut graph = ds.test[idx].0.clone();
        loop {
            match tier.submit(graph) {
                Ok(id) => {
                    truth_of.insert(id, ds.test[idx].1);
                    break;
                }
                Err(SubmitError::Backpressure(g)) => {
                    // Free a slot, keep the response, then retry.
                    graph = g;
                    responses.extend(tier.recv());
                }
                Err(e @ SubmitError::Closed(_)) => return Err(e.into()),
            }
        }
    }
    responses.extend(tier.drain());
    let wall = t_start.elapsed().as_secs_f64();
    assert_eq!(responses.len(), requests, "lost responses");
    let correct = responses
        .iter()
        .filter(|r| truth_of.get(&r.id) == Some(&r.predicted))
        .count();
    println!(
        "\n=== edge serving report ({} on {} shards x {} workers) ===",
        ds.name, shards, workers
    );
    println!("batch size          {batch}");
    println!(
        "requests            {requests} in {wall:.2}s -> {:.0} req/s",
        requests as f64 / wall
    );
    println!(
        "served accuracy     {:.1}%",
        100.0 * correct as f64 / requests.max(1) as f64
    );
    for shard in 0..shards {
        let m = tier.shard_metrics(shard);
        println!(
            "shard {shard}             {} reqs, host p50={:.0}µs p99={:.0}µs p999={:.0}µs, queue p99={:.0}µs",
            m.requests, m.host_us.p50, m.host_us.p99, m.host_us.p999, m.queue_us.p99
        );
    }
    tier.shutdown();

    xla_cross_check(trained);
    Ok(())
}

/// Cross-layer check: run the NEE stage of the same queries through the
/// jax-exported, PJRT-loaded artifact and compare predictions. Needs the
/// `xla-runtime` feature (the `xla` crate is not in the vendored set).
#[cfg(feature = "xla-runtime")]
fn xla_cross_check(trained: &mut TrainedPipeline) {
    use std::path::Path;

    use nysx::runtime::{Manifest, PjrtRuntime, XlaNee};

    eprintln!("\n[4/4] cross-checking L1/L2 artifact (PJRT) against native pipeline");
    let artifacts = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("manifest.json").exists() {
        eprintln!("      SKIPPED: run `make artifacts` first");
        return;
    }
    let manifest = match Manifest::load(&artifacts) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("      SKIPPED (manifest: {e})");
            return;
        }
    };
    let rt = match PjrtRuntime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("      SKIPPED (PJRT CPU: {e})");
            return;
        }
    };
    let model = trained.model().clone();
    let (ds, engine) = trained.parts();
    match XlaNee::new(&rt, &manifest, &model) {
        Ok(nee) => {
            let mut agree = 0usize;
            let check = ds.test.len().min(64);
            for (g, _) in ds.test.iter().take(check) {
                let (c, _) = engine.kernel_vector(g);
                let c = c.to_vec();
                let xla_hv = match nee.project_sign(&c) {
                    Ok(hv) => hv,
                    Err(e) => {
                        eprintln!("      SKIPPED mid-run (xla exec: {e})");
                        return;
                    }
                };
                let hv = nysx::hdc::Hypervector {
                    data: xla_hv
                        .iter()
                        .map(|&v| if v < 0.0 { -1i8 } else { 1 })
                        .collect(),
                };
                let xla_pred = model.reference_prototypes().classify(&hv);
                let (native_pred, _) = engine.classify_kernel_vector(&c);
                if xla_pred == native_pred {
                    agree += 1;
                }
            }
            println!("      XLA NEE vs native: {agree}/{check} predictions agree");
            assert!(agree * 10 >= check * 9, "cross-layer disagreement too high");
        }
        Err(e) => eprintln!("      SKIPPED ({e}) — rebuild artifacts for this d/s"),
    }
}

/// Default build: the vendored crate set has no `xla`, so the PJRT leg
/// is compiled out and the example stays runnable everywhere.
#[cfg(not(feature = "xla-runtime"))]
fn xla_cross_check(_trained: &mut TrainedPipeline) {
    eprintln!(
        "\n[4/4] XLA cross-check skipped (build with --features xla-runtime after `make artifacts`)"
    );
}
